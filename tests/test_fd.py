"""Functional-dependency-aware solving: catalog, inference, FD-reduced
training with closed-form recovery, cache/append threading, and the
append exception-safety guarantees that ride along.

The correctness anchor: with ``f → g`` on every join row, the model
reparametrized onto the reduced space (γ_f = θ_f + Rᵀθ_g, θ_g dropped)
plus the generalized per-root ridge is EXACTLY the full problem after the
inner minimization over θ_g — so FD-reduced training must match the full
solve to numerical precision, while issuing strictly fewer GROUP BY
queries.
"""

import numpy as np
import pytest

import repro.core.categorical as catmod
from repro.core import (
    VERSIONS,
    GLMConfig,
    cofactors_factorized,
    glm_regression,
    linear_regression,
)
from repro.core.categorical import cat_cofactors_factorized
from repro.core.fd import (
    compose_maps,
    expand_cat_cofactors,
    recover_blocks,
)
from repro.core.relation import Relation
from repro.core.store import Store
from repro.data.synthetic import fd_star_schema

CAT2 = ["c0", "c1", "d0", "d1"]
FEATS2 = ["x"] + CAT2


@pytest.fixture()
def bundle():
    b = fd_star_schema(n_cat=2, domain=12, dep_domain=4, n_rows=400, seed=5)
    b.store.infer_fds()
    return b


def _dim_map(store, i: int) -> np.ndarray:
    dim = store.get(f"Dim{i}")
    m = np.full(store.attr_domain(f"c{i}"), -1, dtype=np.int64)
    m[dim.keys[f"c{i}"].astype(np.int64)] = dim.keys[f"d{i}"].astype(np.int64)
    return m


# ---------------------------------------------------------------------------
# Catalog: inference, declaration, reduction planning
# ---------------------------------------------------------------------------

def test_infer_fds_finds_planted(bundle):
    pairs = {(f.lhs, f.rhs) for f in bundle.store.fds()}
    assert ("c0", "d0") in pairs and ("c1", "d1") in pairs
    fd = {(f.lhs, f.rhs): f for f in bundle.store.fds()}[("c0", "d0")]
    assert fd.source == "inferred"
    np.testing.assert_array_equal(fd.mapping, _dim_map(bundle.store, 0))


def test_infer_rejects_non_functions(bundle):
    # domain 12 > dep_domain 4: the reverse direction collides (pigeonhole)
    pairs = {(f.lhs, f.rhs) for f in bundle.store.fds()}
    assert ("d0", "c0") not in pairs


def test_add_fd_declared_and_violations(bundle):
    store = bundle.store
    fd = store.add_fd("c0", "d0")  # upgrade the inferred FD to a contract
    assert fd.source == "declared"
    with pytest.raises(ValueError):
        store.add_fd("d0", "c0")  # not a function
    with pytest.raises(ValueError):
        store.add_fd("c0", "x")  # value column — never a witnessed key pair
    with pytest.raises(ValueError):
        store.add_fd("c0", "d1")  # no relation contains both


def test_reduction_plan_composes_chains():
    # a → b (witness R), b → c (witness S): [a, b, c] reduces to kept [a]
    # with c's map composed through b.
    a = np.array([0, 1, 2, 3], dtype=np.int32)
    b = np.array([0, 0, 1, 1], dtype=np.int32)
    s_b = np.array([0, 1], dtype=np.int32)
    s_c = np.array([1, 0], dtype=np.int32)
    store = Store(
        [
            Relation.from_columns("R", {"a": a, "b": b}, {"v": np.zeros(4)}),
            Relation.from_columns("S", {"b": s_b, "c": s_c}, {"w": np.zeros(2)}),
        ]
    )
    store.infer_fds()
    red = store.fd_reduction(["a", "b", "c"])
    assert red.kept == ["a"]
    assert set(red.dropped) == {"b", "c"}
    root_b, map_b = red.dropped["b"]
    root_c, map_c = red.dropped["c"]
    assert root_b == root_c == "a"
    np.testing.assert_array_equal(map_b, [0, 0, 1, 1])
    np.testing.assert_array_equal(map_c, [1, 1, 0, 0])
    # compose_maps mirrors the plan's chain composition
    np.testing.assert_array_equal(
        compose_maps(map_b, np.array([1, 0], np.int64)), map_c
    )


def test_reduction_trivial_without_fds():
    b = fd_star_schema(n_cat=1, domain=6, dep_domain=3, n_rows=50, seed=0)
    red = b.store.fd_reduction(["c0", "d0"])
    assert red.is_trivial and red.kept == ["c0", "d0"]


# ---------------------------------------------------------------------------
# FD-reduced training ≡ full solve (the tentpole identity)
# ---------------------------------------------------------------------------

def test_fd_reduced_linear_equals_full(bundle):
    store, vorder = bundle.store, bundle.vorder
    full = linear_regression(
        store, vorder, FEATS2, "y", VERSIONS["closed"], backend="numpy",
        categorical=CAT2, use_fds=False,
    )
    red = linear_regression(
        store, vorder, FEATS2, "y", VERSIONS["closed"], backend="numpy",
        categorical=CAT2, use_fds=True,
    )
    assert full.names == red.names  # indistinguishable layout
    np.testing.assert_allclose(red.theta, full.theta, rtol=0, atol=1e-10)


def test_fd_reduced_glm_equals_full(bundle):
    store, vorder = bundle.store, bundle.vorder
    cfg = GLMConfig(family="logistic", ridge=1e-3, tol=1e-14)
    full = glm_regression(
        store, vorder, ["x"], CAT2, "promo", cfg, backend="numpy",
        use_fds=False,
    )
    red = glm_regression(
        store, vorder, ["x"], CAT2, "promo", cfg, backend="numpy",
        use_fds=True,
    )
    assert full.names == red.names
    assert len(red.theta) == len(full.theta)
    np.testing.assert_allclose(red.theta, full.theta, rtol=0, atol=1e-10)
    # the reduced penalized NLL equals the full one at the recovered θ —
    # the inner minimization is exact, not approximate
    assert abs(red.nll - full.nll) < 1e-8


def test_fd_reduction_issues_fewer_group_by_queries(bundle):
    store, vorder = bundle.store, bundle.vorder
    red = store.fd_reduction(CAT2)
    assert set(red.dropped) == {"d0", "d1"}
    stats_full, stats_red = {}, {}
    cat_cofactors_factorized(
        store, vorder, ["x", "y"], CAT2, backend="numpy", stats=stats_full
    )
    cat_cofactors_factorized(
        store, vorder, ["x", "y"], red.kept, backend="numpy",
        stats=stats_red,
    )
    assert stats_red["passes"] == stats_full["passes"] == 1
    assert stats_red["node_visits"] < stats_full["node_visits"]


def test_expand_cat_cofactors_matches_full(bundle):
    store, vorder = bundle.store, bundle.vorder
    red = store.fd_reduction(CAT2)
    full = cat_cofactors_factorized(
        store, vorder, ["x", "y"], CAT2, backend="numpy"
    )
    reduced = cat_cofactors_factorized(
        store, vorder, ["x", "y"], red.kept, backend="numpy"
    )
    assert reduced.num_params < full.num_params  # smaller assembled Gram
    expanded = expand_cat_cofactors(reduced, red)
    assert expanded.column_names() == full.column_names()
    np.testing.assert_allclose(
        expanded.matrix(), full.matrix(), rtol=1e-12, atol=1e-9
    )


def test_recover_blocks_closed_form_identity():
    """Recovery must be the argmin of ||θ_f||² + ||θ_g||² subject to the
    reparametrization θ_f = γ − Rᵀθ_g — checked against a least-squares
    oracle on the equivalent stacked system min ||[Rᵀ; I]·θ_g − [γ; 0]||²."""
    from repro.core.fd import FDReduction

    rng = np.random.default_rng(3)
    m = rng.integers(0, 3, 5).astype(np.int64)  # f (5 ids) -> g (3 ids)
    red = FDReduction(
        order=["f", "g"],
        kept=["f"],
        dropped={"g": ("f", m)},
        domains={"f": 5, "g": 3},
    )
    gamma = rng.normal(size=5)
    blocks = recover_blocks({"f": gamma}, red)
    r = np.zeros((3, 5))
    r[m, np.arange(5)] = 1.0
    a = np.vstack([r.T, np.eye(3)])
    b = np.concatenate([gamma, np.zeros(3)])
    tg = np.linalg.lstsq(a, b, rcond=None)[0]
    np.testing.assert_allclose(blocks["g"], tg, atol=1e-10)
    # reparametrization invariant: θ_f + Rᵀθ_g == γ
    np.testing.assert_allclose(
        blocks["f"] + r.T @ blocks["g"], gamma, atol=1e-12
    )


# ---------------------------------------------------------------------------
# Cache threading: FD signature in keys, warm retrains, sharded path
# ---------------------------------------------------------------------------

def test_cat_cache_key_carries_fd_signature(bundle):
    store, vorder = bundle.store, bundle.vorder
    reduced = store.cat_cofactors(
        vorder, ["x", "y"], CAT2, backend="numpy", reduce_fds=True
    )
    assert list(reduced.cat) == store.fd_reduction(CAT2).kept
    full = store.cat_cofactors(vorder, ["x", "y"], CAT2, backend="numpy")
    assert list(full.cat) == CAT2  # no aliasing between the two entries
    assert store.cache_info()["cat_entries"] == 2
    # dropping the FDs orphans the reduced entry
    store.drop_fd("c0", "d0")
    store.drop_fd("c1", "d1")
    assert store.cache_info()["cat_entries"] == 1


def test_append_maintains_reduced_entries(bundle):
    store, vorder = bundle.store, bundle.vorder
    store.cat_cofactors(
        vorder, ["x", "y"], CAT2, backend="numpy", reduce_fds=True
    )
    rng = np.random.default_rng(9)
    n = 23
    delta = Relation.from_columns(
        "d",
        {f"c{i}": rng.integers(0, 12, n).astype(np.int32) for i in range(2)},
        {
            "x": rng.normal(0, 2, n),
            "y": rng.normal(0, 2, n),
            "promo": rng.integers(0, 2, n).astype(np.float64),
        },
    )
    store.append("Fact", delta)
    warm = store.cat_cofactors(
        vorder, ["x", "y"], CAT2, backend="numpy", reduce_fds=True
    )
    red = store.fd_reduction(CAT2)
    cold = cat_cofactors_factorized(
        store, vorder, ["x", "y"], red.kept, backend="numpy"
    )
    np.testing.assert_allclose(
        warm.matrix(), cold.matrix(), rtol=1e-12, atol=1e-9
    )
    # end-to-end: warm FD-reduced training still equals the full solve
    w = linear_regression(
        store, vorder, FEATS2, "y", VERSIONS["closed"], backend="numpy",
        categorical=CAT2, use_cache=True, use_fds=True,
    )
    f = linear_regression(
        store, vorder, FEATS2, "y", VERSIONS["closed"], backend="numpy",
        categorical=CAT2, use_fds=False,
    )
    np.testing.assert_allclose(w.theta, f.theta, rtol=0, atol=1e-10)


def test_append_extends_mapping_with_new_ids(bundle):
    store = bundle.store
    # a new c0 id with a consistent d0 value extends the map, FD survives
    delta = Relation.from_columns(
        "d", {"c0": [12], "d0": [2]}, {"w0": [0.0]},
        {"c0": 13, "d0": 4},
    )
    store.append("Dim0", delta)
    fd = {(f.lhs, f.rhs): f for f in store.fds()}[("c0", "d0")]
    assert len(fd.mapping) == 13 and fd.mapping[12] == 2


def test_append_falsifies_inferred_fd(bundle):
    store, vorder = bundle.store, bundle.vorder
    store.cat_cofactors(
        vorder, ["x", "y"], CAT2, backend="numpy", reduce_fds=True
    )
    d0 = store.get("Dim0")
    conflict = Relation.from_columns(
        "d",
        {"c0": [0], "d0": [(int(d0.keys["d0"][0]) + 1) % 4]},
        {"w0": [0.0]},
    )
    store.append("Dim0", conflict)
    pairs = {(f.lhs, f.rhs) for f in store.fds()}
    assert ("c0", "d0") not in pairs  # falsified and dropped
    assert ("c1", "d1") in pairs  # untouched
    # entries built under the dead FD are invalidated, and FD-on training
    # falls back to the surviving reduction — still exactly the full solve
    on = linear_regression(
        store, vorder, FEATS2, "y", VERSIONS["closed"], backend="numpy",
        categorical=CAT2, use_fds=True,
    )
    off = linear_regression(
        store, vorder, FEATS2, "y", VERSIONS["closed"], backend="numpy",
        categorical=CAT2, use_fds=False,
    )
    np.testing.assert_allclose(on.theta, off.theta, rtol=0, atol=1e-10)


def test_append_violating_declared_fd_raises_before_mutation(bundle):
    store = bundle.store
    store.add_fd("c0", "d0")
    rows_before = store.get("Dim0").num_rows
    version_before = store.version
    d0 = store.get("Dim0")
    conflict = Relation.from_columns(
        "d",
        {"c0": [0], "d0": [(int(d0.keys["d0"][0]) + 1) % 4]},
        {"w0": [0.0]},
    )
    with pytest.raises(ValueError, match="declared FD"):
        store.append("Dim0", conflict)
    assert store.get("Dim0").num_rows == rows_before
    assert store.version == version_before
    assert ("c0", "d0") in {(f.lhs, f.rhs) for f in store.fds()}


def test_put_reverifies_fds(bundle):
    store = bundle.store
    # replace Dim0 with a version that breaks c0 → d0
    old = store.get("Dim0")
    keys = {
        "c0": np.concatenate([old.keys["c0"], old.keys["c0"][:1]]),
        "d0": np.concatenate(
            [old.keys["d0"], (old.keys["d0"][:1] + 1) % 4]
        ).astype(np.int32),
    }
    bad = Relation.from_columns(
        "Dim0", keys, {"w0": np.zeros(old.num_rows + 1)}, dict(old.domains)
    )
    store.put(bad)
    assert ("c0", "d0") not in {(f.lhs, f.rhs) for f in store.fds()}
    # declared FDs reject the same mutation
    store2 = fd_star_schema(n_cat=1, domain=6, dep_domain=3, n_rows=40,
                            seed=2).store
    store2.add_fd("c0", "d0")
    old2 = store2.get("Dim0")
    bad2 = Relation.from_columns(
        "Dim0",
        {
            "c0": np.concatenate([old2.keys["c0"], old2.keys["c0"][:1]]),
            "d0": np.concatenate(
                [old2.keys["d0"], (old2.keys["d0"][:1] + 1) % 3]
            ).astype(np.int32),
        },
        {"w0": np.zeros(old2.num_rows + 1)},
        dict(old2.domains),
    )
    with pytest.raises(ValueError, match="declared FD"):
        store2.put(bad2)
    assert store2.get("Dim0").num_rows == old2.num_rows  # rolled back


# ---------------------------------------------------------------------------
# Append exception safety (poisoned delta)
# ---------------------------------------------------------------------------

def test_poisoned_delta_invalidates_instead_of_corrupting(bundle, monkeypatch):
    """If a delta fold raises mid-loop, no cache may be left half-updated:
    entries covering the appended relation are invalidated, the catalog is
    unchanged, and the next lookups recompute coherently.  Fold-on-write is
    the eager mode's job — the lazy drain's twin guarantee is covered in
    test_ingest.py."""
    store, vorder = bundle.store, bundle.vorder
    store.maintenance = "eager"  # fold on the write path, as pre-lazy
    cols = ["x", "y"]
    store.cofactors(vorder, cols, backend="numpy")
    store.cat_cofactors(vorder, cols, ["c0"], backend="numpy")
    assert store.cache_info()["entries"] == 1
    assert store.cache_info()["cat_entries"] == 1
    rows_before = store.get("Fact").num_rows
    version_before = store.version

    def boom(*a, **k):
        raise RuntimeError("poisoned delta")

    # the plain cofactor fold runs (and mutates its entry) BEFORE the
    # categorical fold raises — exactly the half-updated hazard
    monkeypatch.setattr(catmod, "cat_cofactors_factorized", boom)
    rng = np.random.default_rng(2)
    n = 11
    delta = Relation.from_columns(
        "d",
        {f"c{i}": rng.integers(0, 12, n).astype(np.int32) for i in range(2)},
        {
            "x": rng.normal(0, 1, n),
            "y": rng.normal(0, 1, n),
            "promo": np.zeros(n),
        },
    )
    with pytest.raises(RuntimeError, match="poisoned delta"):
        store.append("Fact", delta)
    monkeypatch.undo()

    assert store.get("Fact").num_rows == rows_before  # catalog unchanged
    assert store.version == version_before
    assert store.cache_info()["entries"] == 0  # half-updated entry dropped
    assert store.cache_info()["cat_entries"] == 0
    warm = store.cofactors(vorder, cols, backend="numpy")
    cold = cofactors_factorized(store, vorder, cols, backend="numpy")
    np.testing.assert_allclose(
        warm.matrix(), cold.matrix(), rtol=1e-12, atol=1e-9
    )
    # and a later append works and stays exact
    store.append("Fact", delta)
    warm = store.cofactors(vorder, cols, backend="numpy")
    cold = cofactors_factorized(store, vorder, cols, backend="numpy")
    np.testing.assert_allclose(
        warm.matrix(), cold.matrix(), rtol=1e-12, atol=1e-9
    )


# ---------------------------------------------------------------------------
# Distributed path
# ---------------------------------------------------------------------------

def test_sharded_cat_cofactors_fd_reduction(bundle):
    import jax

    from repro.core.distributed import sharded_cat_cofactors

    store = bundle.store
    joined = store.materialize_join()
    x = np.stack(
        [joined.column(f).astype(np.float64) for f in ["x", "y"]], axis=1
    )
    ids = np.stack(
        [joined.column(c).astype(np.int64) for c in CAT2], axis=1
    )
    doms = {c: store.attr_domain(c) for c in CAT2}
    mesh = jax.make_mesh((1,), ("data",))
    red = store.fd_reduction(CAT2)
    reduced = sharded_cat_cofactors(
        x, ids, ["x", "y"], CAT2, doms, mesh, fd=red
    )
    assert list(reduced.cat) == red.kept
    full = sharded_cat_cofactors(x, ids, ["x", "y"], CAT2, doms, mesh)
    expanded = expand_cat_cofactors(reduced, red)
    # both sides accumulate in fp32 on-device — fp32-scale tolerance
    np.testing.assert_allclose(
        expanded.matrix(), full.matrix(), rtol=5e-4, atol=1e-2
    )
