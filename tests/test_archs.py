"""Per-architecture smoke tests (assignment deliverable f).

Every assigned architecture instantiates its REDUCED same-family config and
runs one forward + one train step on CPU, asserting output shapes and
finiteness.  The FULL configs are exercised only via the dry-run.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, get_config, input_specs
from repro.models import model
from repro.train import TrainHParams, init_state, make_train_step

ARCH_NAMES = sorted(ARCHS)
B, S = 2, 32


def smoke_batch(cfg, key, with_labels=True, seq=S):
    s_text = cfg.text_len(seq)
    batch = {"tokens": jax.random.randint(key, (B, s_text), 0, cfg.vocab)}
    if with_labels:
        batch["labels"] = jax.random.randint(key, (B, s_text), 0, cfg.vocab)
    if cfg.is_encoder_decoder:
        batch["frames"] = jax.random.normal(
            key, (B, cfg.n_frames, cfg.d_model), cfg.dtype
        )
    if cfg.n_patches:
        batch["patches"] = jax.random.normal(
            key, (B, cfg.n_patches, cfg.d_model), cfg.dtype
        )
    return batch


@pytest.fixture(scope="module")
def key():
    return jax.random.key(0)


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_forward_shapes_and_finite(name, key):
    cfg = get_config(name, smoke=True)
    params = model.init_params(key, cfg)
    batch = smoke_batch(cfg, key)
    logits, aux = model.forward(params, batch, cfg)
    assert logits.shape == (B, S, model.padded_vocab(cfg))
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_one_train_step_reduces_loss_direction(name, key):
    cfg = get_config(name, smoke=True)
    hp = TrainHParams(peak_lr=1e-3, total_steps=10, warmup_steps=0)
    state = init_state(key, cfg, hp)
    step = jax.jit(make_train_step(cfg, hp))
    batch = smoke_batch(cfg, key)
    losses = []
    for _ in range(3):
        state, m = step(state, batch)  # same batch: loss must fall
        losses.append(float(m["loss"]))
    assert all(np.isfinite(l) for l in losses)
    assert losses[-1] < losses[0]
    assert int(state.step) == 3


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_decode_step_shapes(name, key):
    cfg = get_config(name, smoke=True)
    params = model.init_params(key, cfg)
    cache = model.init_cache(cfg, B, max_len=64)
    tok = jnp.zeros((B, 1), jnp.int32)
    logits, cache2 = model.decode_step(
        params, tok, cache, jnp.asarray(0, jnp.int32), cfg
    )
    assert logits.shape == (B, model.padded_vocab(cfg))
    assert bool(jnp.all(jnp.isfinite(logits)))
    # cache structure preserved
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_prefill_matches_forward_last_position(name, key):
    cfg = get_config(name, smoke=True)
    params = model.init_params(key, cfg)
    batch = smoke_batch(cfg, key, with_labels=False)
    logits_fwd, _ = model.forward(params, batch, cfg)
    logits_pre, _ = model.prefill(params, batch, cfg, max_len=64)
    np.testing.assert_allclose(
        np.asarray(logits_pre),
        np.asarray(logits_fwd[:, -1]),
        rtol=2e-3, atol=2e-3,
    )


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_microbatched_grads_match_full_batch(name, key):
    """Gradient accumulation must be algebraically identical (fp32 accum)."""
    cfg = get_config(name, smoke=True)
    cfg_mb = dataclasses.replace(cfg, microbatches=2)
    hp = TrainHParams(peak_lr=1e-3, total_steps=10, warmup_steps=1)
    state = init_state(key, cfg, hp)
    batch = smoke_batch(cfg, key)
    s1, m1 = jax.jit(make_train_step(cfg, hp))(state, batch)
    s2, m2 = jax.jit(make_train_step(cfg_mb, hp))(state, batch)
    # microbatching changes averaging order; losses agree to fp tolerance
    np.testing.assert_allclose(
        float(m1["loss"]), float(m2["loss"]), rtol=5e-3
    )


def test_input_specs_cover_all_cells():
    """Every runnable (arch × shape) cell must produce well-formed specs."""
    n = 0
    for cfg in ARCHS.values():
        for shape in SHAPES.values():
            if shape.name in cfg.skip_shapes:
                continue
            specs = input_specs(cfg, shape)
            assert all(hasattr(s, "shape") for s in jax.tree.leaves(specs))
            n += 1
    assert n == 33  # 40 cells - 7 long_500k skips


def test_param_counts_match_known_sizes():
    """Analytic parameter counts should land near published model sizes."""
    expect = {
        "smollm-135m": (0.10e9, 0.2e9),
        "deepseek-67b": (60e9, 72e9),
        "olmo-1b": (0.9e9, 1.5e9),
        "granite-20b": (18e9, 23e9),
        "xlstm-1.3b": (0.9e9, 4.0e9),
        "mixtral-8x7b": (43e9, 50e9),
        "llava-next-mistral-7b": (6.5e9, 8e9),
        "jamba-1.5-large-398b": (330e9, 420e9),
    }
    for name, (lo, hi) in expect.items():
        total = ARCHS[name].param_counts()["total"]
        assert lo <= total <= hi, (name, total)
    # MoE active << total
    for name in ("mixtral-8x7b", "qwen2-moe-a2.7b", "jamba-1.5-large-398b"):
        c = ARCHS[name].param_counts()
        assert c["active"] < 0.55 * c["total"], (name, c)
