"""Concurrency contract checker + lockset sanitizer, against planted defects.

Static side: each fixture module plants exactly one class of violation —
lock-order inversion (lexical and via call-edge inference), unguarded
writes, in-place COW mutation, wait-while-holding, non-reentrant
re-acquisition, frozen-field rebinding — and the checker must flag it,
while a contract-respecting module stays clean.  The CLI's ratchet
baseline must pass old violations and fail new ones.

Dynamic side: a standalone ``LockSanitizer`` must report the planted
empty-lockset interleaving, runtime order inversions, and
wait-while-holding — and stay silent for consistently-locked access.
"""

import threading

import pytest

from repro.analysis import cli, cow, lockcheck
from repro.analysis.sanitizer import (
    LockSanitizer,
    SanitizedCondition,
    SanitizedLock,
)

# ---------------------------------------------------------------------------
# fixtures: one planted defect each (class/attr names match the declared
# contracts, so the default contract set applies)
# ---------------------------------------------------------------------------

FIXTURE_LOCK_ORDER = '''
class FactorizedService:
    def bad(self):
        with self._stats_lock:   # leaf lock first ...
            with self._lock:     # ... then the queue lock: inversion
                self._seq += 1
'''

FIXTURE_LOCK_ORDER_VIA_CALL = '''
class ViewCache:
    def bad(self, store, delta):
        with self._mu:
            store.append("Fact", delta)  # acquires Store._mutate_lock
'''

FIXTURE_UNGUARDED_WRITE = '''
class Store:
    def bad(self):
        self._relations = {}  # catalog swap without the mutate lock
'''

FIXTURE_COW_MUTATION = '''
class Store:
    def bad(self, rel):
        with self._mutate_lock:
            self._relations[rel.name] = rel  # in-place: snapshots see it
            self._fds.update({})             # ditto
'''

FIXTURE_WAIT_HOLDING = '''
class FactorizedService:
    def bad(self):
        with self._cycle_lock:
            with self._lock:
                self._not_full.wait(0.1)  # cycle lock stays held
'''

FIXTURE_SELF_DEADLOCK = '''
class FactorizedService:
    def bad(self):
        with self._lock:
            with self._lock:  # plain Lock: guaranteed deadlock
                pass
'''

FIXTURE_FROZEN_FIELD = '''
def retune(policy):
    policy.backoff = 2.0  # RetryPolicy is replace-only
'''

FIXTURE_CLEAN = '''
class FactorizedService:
    def good(self):
        with self._cycle_lock:
            with self._lock:
                self._seq += 1
                self._not_full.notify_all()
            with self._stats_lock:
                self._tenants["a"] = 1


class Store:
    def good(self, rel):
        with self._mutate_lock:
            self._relations = {**self._relations, rel.name: rel}
            self.view_cache.invalidate("x")
'''

PLANTED = [
    ("lock-order", FIXTURE_LOCK_ORDER),
    ("lock-order", FIXTURE_LOCK_ORDER_VIA_CALL),
    ("guarded-by", FIXTURE_UNGUARDED_WRITE),
    ("cow-mutation", FIXTURE_COW_MUTATION),
    ("condition-wait", FIXTURE_WAIT_HOLDING),
    ("self-deadlock", FIXTURE_SELF_DEADLOCK),
    ("frozen-field", FIXTURE_FROZEN_FIELD),
]


# ---------------------------------------------------------------------------
# static checker
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rule,src", PLANTED)
def test_planted_defect_is_caught(rule, src):
    findings = lockcheck.check_source(src) + cow.check_source(src)
    assert any(f.rule == rule for f in findings), (
        rule, [f.render() for f in findings])


def test_clean_module_has_no_findings():
    findings = (lockcheck.check_source(FIXTURE_CLEAN)
                + cow.check_source(FIXTURE_CLEAN))
    assert findings == [], [f.render() for f in findings]


def test_suppression_comment_silences_finding():
    src = FIXTURE_UNGUARDED_WRITE.replace(
        "self._relations = {}",
        "self._relations = {}  # lockcheck: test-only suppression")
    assert lockcheck.check_source(src) == []


def test_cow_mutation_flagged_even_under_lock():
    """The COW lint is orthogonal to locking: holding the mutate lock does
    not make an in-place edit of an aliased snapshot map safe."""
    findings = lockcheck.check_source(FIXTURE_COW_MUTATION)
    assert not findings  # guarded-by is satisfied (lock held) ...
    findings = cow.check_source(FIXTURE_COW_MUTATION)
    assert {f.detail for f in findings} == {
        "_relations|setitem", "_fds|update"}  # ... but COW is not


def test_fingerprint_is_line_number_stable():
    shifted = "\n\n\n" + FIXTURE_UNGUARDED_WRITE
    a = lockcheck.check_source(FIXTURE_UNGUARDED_WRITE)
    b = lockcheck.check_source(shifted)
    assert [f.fingerprint for f in a] == [f.fingerprint for f in b]
    assert a[0].line != b[0].line


# ---------------------------------------------------------------------------
# CLI + ratchet baseline
# ---------------------------------------------------------------------------

def _write(tmp_path, name, src):
    p = tmp_path / name
    p.write_text(src)
    return p


@pytest.mark.parametrize("rule,src", PLANTED)
def test_cli_exits_nonzero_on_planted_fixture(tmp_path, rule, src):
    p = _write(tmp_path, "fixture.py", src)
    assert cli.main([str(p)]) == 1


def test_cli_exits_zero_on_clean_module(tmp_path):
    p = _write(tmp_path, "clean.py", FIXTURE_CLEAN)
    assert cli.main([str(p)]) == 0


def test_cli_exits_zero_on_repo_with_committed_baseline():
    # The shipped configuration: src/repro is clean against the committed
    # ratchet file.
    import pathlib
    repo = pathlib.Path(__file__).resolve().parent.parent
    assert cli.main([str(repo / "src" / "repro"), "--baseline",
                     str(repo / "analysis_baseline.json")]) == 0


def test_baseline_ratchet_old_passes_new_fails(tmp_path):
    fixtures = tmp_path / "pkg"
    fixtures.mkdir()
    _write(fixtures, "legacy.py", FIXTURE_UNGUARDED_WRITE)
    baseline = tmp_path / "baseline.json"
    # Ratchet the legacy debt ...
    assert cli.main([str(fixtures), "--write-baseline", str(baseline)]) == 0
    # ... the old violation no longer fails the build ...
    assert cli.main([str(fixtures), "--baseline", str(baseline)]) == 0
    # ... but a NEW violation in another file does ...
    _write(fixtures, "fresh.py", FIXTURE_COW_MUTATION)
    assert cli.main([str(fixtures), "--baseline", str(baseline)]) == 1
    # ... and fixing it goes back to green without touching the baseline.
    (fixtures / "fresh.py").unlink()
    assert cli.main([str(fixtures), "--baseline", str(baseline)]) == 0


# ---------------------------------------------------------------------------
# dynamic sanitizer (standalone: real threads, wrapped locks)
# ---------------------------------------------------------------------------

def _locks(san):
    a = SanitizedLock(san, "Store._mutate_lock", threading.RLock())
    b = SanitizedLock(san, "ViewCache._mu", threading.RLock())
    return a, b


def test_sanitizer_reports_empty_lockset_interleaving():
    san = LockSanitizer()
    lock_a, lock_b = _locks(san)
    field = "FactorizedService._seq"  # declared policy: full

    # t1 must stay alive until t2 has accessed: a joined thread's ident can
    # be reused, which would make the two accesses look single-threaded.
    first_done = threading.Event()
    second_done = threading.Event()

    def first():
        with lock_a:
            san._access(field, "write")
        first_done.set()
        second_done.wait(5)

    def second():
        first_done.wait(5)
        with lock_b:
            san._access(field, "write")
        second_done.set()

    t1 = threading.Thread(target=first)
    t2 = threading.Thread(target=second)
    t1.start(); t2.start()
    t2.join(); t1.join()
    assert [r.field for r in san.empty_locksets] == [field]
    with pytest.raises(AssertionError):
        san.assert_clean()


def test_sanitizer_consistent_lock_keeps_lockset():
    san = LockSanitizer()
    lock_a, _ = _locks(san)
    field = "FactorizedService._seq"

    def worker():
        with lock_a:
            san._access(field, "write")

    for _ in range(2):
        t = threading.Thread(target=worker)
        t.start(); t.join()
    assert san.empty_locksets == []
    san.assert_clean()


def test_sanitizer_memo_policy_fields_are_exempt():
    san = LockSanitizer()
    field = "Store._enc_cols"  # declared policy: memo (idempotent fills)

    def worker():
        san._access(field, "write")  # no lock at all

    for _ in range(2):
        t = threading.Thread(target=worker)
        t.start(); t.join()
    san.assert_clean()


def test_sanitizer_runtime_order_assertion():
    san = LockSanitizer()
    mutate, vc_mu = _locks(san)
    with vc_mu:          # ViewCache._mu first ...
        with mutate:     # ... then Store._mutate_lock: declared inversion
            pass
    assert len(san.order_violations) == 1
    v = san.order_violations[0]
    assert v.acquired == "Store._mutate_lock"
    assert "ViewCache._mu" in v.held


def test_sanitizer_allows_declared_nesting_and_reentrancy():
    san = LockSanitizer()
    mutate, vc_mu = _locks(san)
    with mutate:
        with mutate:      # RLock re-entry is fine
            with vc_mu:   # declared edge mutate -> vc
                pass
    san.assert_clean()
    assert san.acquisitions["Store._mutate_lock"] == 2


def test_sanitized_condition_flags_wait_while_holding():
    san = LockSanitizer()
    cycle = SanitizedLock(
        san, "FactorizedService._cycle_lock", threading.RLock())
    queue = SanitizedLock(san, "FactorizedService._lock", threading.Lock())
    cond = SanitizedCondition(san, "FactorizedService._not_full", queue)

    with cycle:
        with cond:                 # acquires the wrapped queue lock
            cond.wait(timeout=0.01)  # cycle lock still held -> violation
    assert len(san.wait_violations) == 1
    assert san.wait_violations[0].held == (
        "FactorizedService._cycle_lock",)

    # waiting with only the condition's own lock held is clean
    san2 = LockSanitizer()
    queue2 = SanitizedLock(san2, "FactorizedService._lock", threading.Lock())
    cond2 = SanitizedCondition(san2, "FactorizedService._not_full", queue2)
    with cond2:
        cond2.wait(timeout=0.01)
    san2.assert_clean()


def test_sanitized_condition_notify_roundtrip():
    """wait/notify across threads works through the wrapper (the portable
    Condition fallbacks route through SanitizedLock.acquire/release)."""
    san = LockSanitizer()
    queue = SanitizedLock(san, "FactorizedService._lock", threading.Lock())
    cond = SanitizedCondition(san, "FactorizedService._not_full", queue)
    ready = []

    def waiter():
        with cond:
            while not ready:
                cond.wait(timeout=1.0)

    t = threading.Thread(target=waiter)
    t.start()
    with cond:
        ready.append(1)
        cond.notify_all()
    t.join(timeout=5)
    assert not t.is_alive()
    san.assert_clean()
    # bookkeeping survived the wait's release/re-acquire cycle
    assert san._held.stack == []
