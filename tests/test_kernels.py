"""Pallas kernel correctness: shape/dtype sweeps vs the pure-jnp oracles.

All kernels run in interpret mode on CPU (the kernels TARGET TPU; interpret
executes the kernel body in Python), asserting allclose against ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash import flash_kernel_call
from repro.kernels.gram import gram_kernel_call

KEY = jax.random.key(42)


def rand(shape, dtype, key=KEY):
    x = jax.random.normal(key, shape, jnp.float32) * 3.0
    return x.astype(dtype)


# ---------------------------------------------------------------------------
# gram
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m", [1, 7, 8, 129, 1000])
@pytest.mark.parametrize("k", [1, 3, 64, 130])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gram_sweep(m, k, dtype):
    x = rand((m, k), dtype)
    out = ops.gram(x)
    expect = ref.gram_ref(x)
    # fp32 accumulation order differs between the blocked kernel and the
    # one-shot oracle: near-zero entries see ~1e-3 relative noise at
    # m=1000 — atol covers them, rtol still catches indexing bugs.
    rtol, atol = (1e-3, 5e-2) if dtype == jnp.float32 else (3e-2, 3e-2)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(expect), rtol=rtol, atol=atol
    )


def test_gram_blocked_padding_exact():
    """Padding rows/cols must contribute exactly nothing."""
    x = rand((130, 5), jnp.float32)
    out = ops.gram(x, bm=64, bk=128)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.gram_ref(x)), rtol=1e-5, atol=1e-4
    )


def test_gram_kernel_call_requires_aligned():
    with pytest.raises(AssertionError):
        gram_kernel_call(jnp.zeros((100, 128)), bm=64, bk=128)


# ---------------------------------------------------------------------------
# segment gram
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,g", [(5, 1), (64, 4), (200, 17), (1000, 3)])
@pytest.mark.parametrize("k", [2, 9])
def test_segment_gram_sweep(m, g, k):
    x = rand((m, k), jnp.float32)
    seg = jax.random.randint(KEY, (m,), 0, g)
    out = ops.segment_gram(x, seg, g)
    expect = ref.segment_gram_ref(x, seg, g)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(expect), rtol=1e-4, atol=1e-4
    )


def test_segment_gram_group_chunking():
    """Group counts above the VMEM budget must chunk transparently."""
    m, k = 64, 40  # 40*40*4 = 6.4 KB per group
    x = rand((m, k), jnp.float32)
    g = 4000  # 4000 groups * 6.4KB > 8MB budget -> chunked path
    seg = jax.random.randint(KEY, (m,), 0, g)
    out = ops.segment_gram(x, seg, g)
    expect = ref.segment_gram_ref(x, seg, g)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(expect), rtol=1e-4, atol=1e-4
    )


@pytest.mark.parametrize("budget", [40, 100, 200])
def test_segment_gram_forced_chunking_matches_unchunked(budget):
    """Drive the g_chunk < num_groups branch directly with a tiny VMEM
    budget: the rebased-id chunked result must match the one-shot path
    and the oracle."""
    m, k, g = 57, 3, 10  # k*k*4 = 36 bytes/group: budget 40 -> 1 grp/chunk
    x = rand((m, k), jnp.float32)
    seg = jax.random.randint(KEY, (m,), 0, g)
    chunked = ops.segment_gram(x, seg, g, vmem_budget=budget)
    unchunked = ops.segment_gram(x, seg, g)
    np.testing.assert_allclose(
        np.asarray(chunked), np.asarray(unchunked), rtol=1e-6, atol=1e-6
    )
    np.testing.assert_allclose(
        np.asarray(chunked),
        np.asarray(ref.segment_gram_ref(x, seg, g)),
        rtol=1e-4, atol=1e-4,
    )


# ---------------------------------------------------------------------------
# moments
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m", [1, 8, 100, 4096])
def test_moments_sweep(m):
    x = rand((m,), jnp.float32)
    s, mx, cnt = ops.moments(x)
    es, emx, ecnt = ref.moments_ref(x)
    np.testing.assert_allclose(float(s), float(es), rtol=1e-5)
    np.testing.assert_allclose(float(mx), float(emx), rtol=1e-6)
    assert cnt == ecnt


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize(
    "b,sq,sk,h,kh,d,causal,window",
    [
        (2, 64, 64, 4, 2, 32, True, None),   # GQA causal
        (1, 48, 48, 2, 2, 16, True, 16),     # sliding window
        (2, 24, 72, 3, 1, 64, False, None),  # MQA, non-causal, ragged blocks
        (1, 16, 128, 4, 4, 128, True, None), # long kv, MXU-width head
    ],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_sweep(b, sq, sk, h, kh, d, causal, window, dtype):
    ks = jax.random.split(KEY, 3)
    q = rand((b, sq, h, d), dtype, ks[0])
    k = rand((b, sk, kh, d), dtype, ks[1])
    v = rand((b, sk, kh, d), dtype, ks[2])
    out = ops.flash_attention(
        q, k, v, causal=causal, window=window, bq=16, bk=16
    )
    g = h // kh
    qr = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kr = jnp.repeat(k, g, axis=2).transpose(0, 2, 1, 3).reshape(b * h, sk, d)
    vr = jnp.repeat(v, g, axis=2).transpose(0, 2, 1, 3).reshape(b * h, sk, d)
    expect = (
        ref.flash_ref(qr, kr, vr, causal=causal, window=window)
        .reshape(b, h, sq, d)
        .transpose(0, 2, 1, 3)
    )
    tol = 1e-4 if dtype == jnp.float32 else 4e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32),
        np.asarray(expect, np.float32),
        rtol=tol, atol=tol,
    )


def test_flash_matches_model_chunked_path():
    """The Pallas kernel and the jnp online-softmax path must agree."""
    from repro.models.attention import chunked_attention

    b, s, h, kh, d = 2, 64, 4, 2, 32
    ks = jax.random.split(KEY, 3)
    q = rand((b, s, h, d), jnp.float32, ks[0])
    k = rand((b, s, kh, d), jnp.float32, ks[1])
    v = rand((b, s, kh, d), jnp.float32, ks[2])
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s)).astype(jnp.int32)
    out_jnp = chunked_attention(
        q, k, v, pos, pos, causal=True, window=None,
        out_dtype=jnp.float32, q_chunk=16, k_chunk=16,
    )
    out_pl = ops.flash_attention(q, k, v, causal=True, bq=16, bk=16)
    np.testing.assert_allclose(
        np.asarray(out_pl), np.asarray(out_jnp), rtol=1e-4, atol=1e-4
    )


@pytest.mark.parametrize("m", [5, 64, 200])
@pytest.mark.parametrize("doms", [[3], [4, 7], [5, 2, 9]])
def test_multi_segment_gram_matches_per_column(m, doms):
    """The fused multi-column kernel == one segment_gram per column, while
    streaming the data block once."""
    k = 4
    x = rand((m, k), jnp.float32)
    segs = jnp.stack(
        [
            jax.random.randint(jax.random.key(i + 1), (m,), 0, d)
            for i, d in enumerate(doms)
        ],
        axis=1,
    )
    outs = ops.multi_segment_gram(x, segs, doms)
    assert len(outs) == len(doms)
    for i, d in enumerate(doms):
        expect = ref.segment_gram_ref(x, segs[:, i], d)
        np.testing.assert_allclose(
            np.asarray(outs[i]), np.asarray(expect), rtol=1e-4, atol=1e-4
        )


def test_multi_segment_gram_vmem_fallback_matches_fused():
    """Over-budget accumulators fall back to per-column (chunked)
    segment_gram — same numbers either way."""
    m, k, doms = 120, 3, [10, 6]
    x = rand((m, k), jnp.float32)
    segs = jnp.stack(
        [
            jax.random.randint(jax.random.key(i + 9), (m,), 0, d)
            for i, d in enumerate(doms)
        ],
        axis=1,
    )
    fused = ops.multi_segment_gram(x, segs, doms)
    tiny = ops.multi_segment_gram(x, segs, doms, vmem_budget=200)
    for a, b in zip(fused, tiny):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5
        )


def test_multi_segment_gram_empty_columns():
    x = rand((10, 2), jnp.float32)
    assert ops.multi_segment_gram(x, jnp.zeros((10, 0), jnp.int32), []) == []


# ---------------------------------------------------------------------------
# fused traversal node: segment_view / segment_blocks
# ---------------------------------------------------------------------------

def _sv_inputs(m, k, g, dtype=jnp.float32, key=KEY):
    ks = jax.random.split(key, 4)
    c = rand((m,), dtype, ks[0])
    x = rand((m,), dtype, ks[1])
    l = rand((m, k), dtype, ks[2])
    q = rand((m, k, k), dtype, ks[3])
    seg = jax.random.randint(KEY, (m,), 0, g)
    return c, x, l, q, seg


def _assert_view_eq(got, expect, rtol=1e-5, atol=1e-4):
    for a, b in zip(got, expect):
        assert (a is None) == (b is None)
        if a is not None:
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), rtol=rtol, atol=atol
            )


@pytest.mark.parametrize("m,g", [(5, 1), (64, 4), (200, 17), (1000, 3)])
@pytest.mark.parametrize("k", [1, 4])
@pytest.mark.parametrize("degree", [1, 2])
@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_segment_view_sweep(m, g, k, degree, impl):
    """One fused dispatch == materialized extend + per-block scatter."""
    c, x, l, q, seg = _sv_inputs(m, k, g)
    got = ops.segment_view(
        c, x, l, q if degree == 2 else None, seg, g, degree=degree, impl=impl
    )
    expect = ref.segment_view_ref(c, x, l, q, seg, g, degree=degree)
    _assert_view_eq(got, expect)


@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_segment_view_k0_padding(impl):
    """Views with no features yet (k=0): the Pallas path pads a zero
    feature column — the slice back must be exact."""
    m, g = 37, 5
    c, x, _, _, seg = _sv_inputs(m, 1, g)
    l = jnp.zeros((m, 0), jnp.float32)
    q = jnp.zeros((m, 0, 0), jnp.float32)
    got = ops.segment_view(c, x, l, q, seg, g, degree=2, impl=impl)
    expect = ref.segment_view_ref(c, x, l, q, seg, g, degree=2)
    _assert_view_eq(got, expect)
    assert got[1].shape == (g, 1) and got[2].shape == (g, 1, 1)


@pytest.mark.parametrize("impl", ["xla", "pallas"])
@pytest.mark.parametrize("budget", [100, 400, 1000])
def test_segment_view_forced_chunking(impl, budget):
    """A tiny vmem_budget drives the rebased-id group-chunking branch —
    same numbers as the one-shot path and the oracle (mirrors
    test_segment_gram_forced_chunking_matches_unchunked)."""
    m, k, g = 157, 3, 11  # (k+2)^2*4 = 100 B/group: budget 100 -> chunked
    c, x, l, q, seg = _sv_inputs(m, k, g)
    chunked = ops.segment_view(
        c, x, l, q, seg, g, degree=2, impl=impl, vmem_budget=budget
    )
    one_shot = ops.segment_view(c, x, l, q, seg, g, degree=2, impl=impl)
    _assert_view_eq(chunked, one_shot, rtol=1e-6, atol=1e-6)
    _assert_view_eq(chunked, ref.segment_view_ref(c, x, l, q, seg, g))


@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_segment_view_empty_segments(impl):
    """Groups with no rows must come out exactly zero (not NaN/garbage),
    and out-of-range ids must drop."""
    m, k, g = 40, 2, 8
    c, x, l, q, _ = _sv_inputs(m, k, g)
    seg = jnp.where(jnp.arange(m) % 2 == 0, 1, 6)  # only groups 1 and 6
    got = ops.segment_view(c, x, l, q, seg, g, degree=2, impl=impl)
    expect = ref.segment_view_ref(c, x, l, q, seg, g, degree=2)
    _assert_view_eq(got, expect)
    empty = [i for i in range(g) if i not in (1, 6)]
    assert np.all(np.asarray(got[0])[empty] == 0.0)
    assert np.all(np.asarray(got[2])[empty] == 0.0)


@pytest.mark.parametrize("impl", ["xla", "pallas"])
@pytest.mark.parametrize("degree", [1, 2])
def test_segment_view_single_group(impl, degree):
    """num_groups=1 (aggregating an attribute fully out) — the packed
    matrix collapses to the global extended cofactor block."""
    m, k = 63, 3
    c, x, l, q, _ = _sv_inputs(m, k, 4)
    seg = jnp.zeros((m,), jnp.int32)
    got = ops.segment_view(
        c, x, l, q if degree == 2 else None, seg, 1, degree=degree, impl=impl
    )
    expect = ref.segment_view_ref(c, x, l, q, seg, 1, degree=degree)
    _assert_view_eq(got, expect)


def test_segment_view_zero_rows():
    c = jnp.zeros((0,), jnp.float32)
    l = jnp.zeros((0, 2), jnp.float32)
    q = jnp.zeros((0, 2, 2), jnp.float32)
    seg = jnp.zeros((0,), jnp.int32)
    got = ops.segment_view(c, c, l, q, seg, 3, degree=2, impl="xla")
    assert got[0].shape == (3,) and np.all(np.asarray(got[0]) == 0.0)


def test_segment_view_fp64_xla():
    """Under x64 the fused XLA path accumulates in fp64 and matches the
    fp64 oracle bit-for-bit-scale (1e-15 rel), preserving the numpy-oracle
    comparisons the engine's property tests rely on."""
    from jax.experimental import enable_x64

    with enable_x64():
        rng = np.random.default_rng(3)
        m, k, g = 200, 3, 7
        c = jnp.asarray(rng.standard_normal(m))
        x = jnp.asarray(rng.standard_normal(m))
        l = jnp.asarray(rng.standard_normal((m, k)))
        q = jnp.asarray(rng.standard_normal((m, k, k)))
        seg = jnp.asarray(rng.integers(0, g, m).astype(np.int32))
        assert c.dtype == jnp.float64
        got = ops.segment_view(c, x, l, q, seg, g, degree=2, impl="xla")
        expect = ref.segment_view_ref(c, x, l, q, seg, g, degree=2)
        assert got[0].dtype == jnp.float64
        _assert_view_eq(got, expect, rtol=1e-13, atol=1e-13)


def test_segment_view_rejects_bad_degree():
    c, x, l, q, seg = _sv_inputs(8, 2, 2)
    with pytest.raises(ValueError):
        ops.segment_view(c, x, l, q, seg, 2, degree=3)


@pytest.mark.parametrize("m,g", [(5, 1), (200, 17)])
@pytest.mark.parametrize("degree", [0, 1, 2])
@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_segment_blocks_sweep(m, g, degree, impl):
    """One multi-block reduce == one scatter per block."""
    k = 3
    c, _, l, q, seg = _sv_inputs(m, k, g)
    got = ops.segment_blocks(
        c,
        l if degree >= 1 else None,
        q if degree == 2 else None,
        seg,
        g,
        degree=degree,
        impl=impl,
    )
    expect = ref.segment_blocks_ref(c, l, q, seg, g, degree=degree)
    _assert_view_eq(got, expect)


@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_segment_blocks_forced_chunking(impl):
    m, k, g = 91, 2, 9
    c, _, l, q, seg = _sv_inputs(m, k, g)
    chunked = ops.segment_blocks(
        c, l, q, seg, g, degree=2, impl=impl, vmem_budget=80
    )
    one_shot = ops.segment_blocks(c, l, q, seg, g, degree=2, impl=impl)
    _assert_view_eq(chunked, one_shot, rtol=1e-6, atol=1e-6)
    _assert_view_eq(chunked, ref.segment_blocks_ref(c, l, q, seg, g))


def test_group_ids_device_matches_np_unique():
    """The device sort-based grouping is bit-compatible with the host
    np.unique path: same segment ids, same group numbering (ascending key
    order), same first-occurrence gather indices."""
    rng = np.random.default_rng(7)
    for n, dom in [(1, 1), (37, 5), (500, 40), (64, 64)]:
        key = rng.integers(0, dom, n).astype(np.int64)
        uniq, first, inv = np.unique(key, return_index=True, return_inverse=True)
        seg, num, dfirst = ops.group_ids_device(key)
        assert num == len(uniq)
        np.testing.assert_array_equal(np.asarray(seg), inv.astype(np.int32))
        np.testing.assert_array_equal(key[dfirst], uniq)
        # ties resolve to identical gather targets: same key values
        np.testing.assert_array_equal(key[dfirst], key[first])


def test_group_ids_device_empty():
    seg, num, first = ops.group_ids_device(np.zeros((0,), np.int64))
    assert num == 0 and seg.shape == (0,) and first.shape == (0,)
