"""Training substrate: optimizers, compression, checkpointing, loop envelope."""

import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.tokens import TokenPipeline
from repro.train import (
    Checkpointer,
    LoopConfig,
    TrainHParams,
    init_state,
    make_train_step,
    run_loop,
)
from repro.train import compression as comp
from repro.train import optim
from repro.train.checkpoint import latest_step, restore, save

KEY = jax.random.key(0)


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------

def quad_loss(p):
    return jnp.sum((p["w"] - 3.0) ** 2) + jnp.sum((p["b"] + 1.0) ** 2)


@pytest.mark.parametrize("name", ["sgd", "adamw", "adafactor"])
def test_optimizers_descend_quadratic(name):
    params = {"w": jnp.zeros((4, 8)), "b": jnp.zeros((8,))}
    opt = optim.make_optimizer(
        name, lambda s: jnp.asarray(0.1), weight_decay=0.0
    )
    state = opt.init(params)
    for step in range(200):
        g = jax.grad(quad_loss)(params)
        upd, state = opt.update(g, state, params, jnp.asarray(step))
        params = jax.tree.map(lambda p, u: p + u, params, upd)
    assert float(quad_loss(params)) < 0.1 * float(
        quad_loss({"w": jnp.zeros((4, 8)), "b": jnp.zeros((8,))})
    )


def test_adafactor_state_is_factored():
    params = {"w": jnp.zeros((64, 128)), "v": jnp.zeros((64,))}
    opt = optim.adafactor(lambda s: 0.01)
    st = opt.init(params)
    assert set(st["v"]["w"]) == {"vr", "vc"}
    assert st["v"]["w"]["vr"].shape == (64,)
    assert st["v"]["w"]["vc"].shape == (128,)
    assert set(st["v"]["v"]) == {"v"}  # vectors stay unfactored


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 10.0)}
    clipped, norm = optim.clip_by_global_norm(g, 1.0)
    assert float(norm) > 1.0
    n2 = optim.global_norm(clipped)
    np.testing.assert_allclose(float(n2), 1.0, rtol=1e-5)


def test_warmup_cosine_shape():
    sched = optim.warmup_cosine(1e-3, 1000, warmup_steps=100)
    assert float(sched(jnp.asarray(0))) == 0.0
    np.testing.assert_allclose(float(sched(jnp.asarray(100))), 1e-3, rtol=1e-5)
    assert float(sched(jnp.asarray(1000))) < 2e-4


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

def test_quantize_roundtrip_bounds():
    x = jax.random.normal(KEY, (1000,)) * 5
    q, scale = comp.quantize_int8(x)
    err = jnp.abs(comp.dequantize_int8(q, scale) - x)
    assert float(jnp.max(err)) <= float(scale) * 0.5 + 1e-6


def test_error_feedback_telescopes():
    """Mean compressed update over many steps converges to the true mean
    gradient — the error-feedback guarantee."""
    g = jax.random.normal(KEY, (256,))
    err = {"g": jnp.zeros((256,))}
    total = jnp.zeros((256,))
    n = 200
    for _ in range(n):
        out, err = comp.compress_decompress({"g": g}, err)
        total = total + out["g"]
    np.testing.assert_allclose(
        np.asarray(total / n), np.asarray(g), atol=1e-3
    )


def test_compressed_psum_matches_mean():
    """shard_map wiring on a 1-device mesh: psum of int8 == plain mean."""
    mesh = jax.make_mesh((1,), ("data",))
    g = {"w": jax.random.normal(KEY, (8, 8))}
    e = comp.init_error_state(g)

    from functools import partial
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map

    @partial(
        shard_map, mesh=mesh,
        in_specs=(P("data"), P("data")), out_specs=(P("data"), P("data")),
    )
    def fn(gs, es):
        return comp.compressed_psum(gs, es, ("data",))

    out, err = fn(g, e)
    np.testing.assert_allclose(
        np.asarray(out["w"]), np.asarray(g["w"]), atol=0.05
    )
    # feedback + dequantized output reconstruct the input exactly
    np.testing.assert_allclose(
        np.asarray(out["w"] + err["w"]), np.asarray(g["w"]), atol=1e-6
    )


def test_training_with_compression_converges():
    cfg = get_config("smollm-135m", smoke=True)
    hp = TrainHParams(
        peak_lr=1e-3, total_steps=20, warmup_steps=1, compress_grads=True
    )
    state = init_state(KEY, cfg, hp)
    assert state.err is not None
    step = jax.jit(make_train_step(cfg, hp))
    pipe = TokenPipeline(cfg.vocab, 32, 4, seed=0)
    losses = []
    for i in range(10):
        state, m = step(state, pipe.batch_at(i % 2))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def _tiny_state():
    cfg = get_config("smollm-135m", smoke=True)
    hp = TrainHParams(total_steps=10)
    return cfg, hp, init_state(KEY, cfg, hp)


def test_checkpoint_roundtrip_exact():
    cfg, hp, state = _tiny_state()
    with tempfile.TemporaryDirectory() as d:
        save(d, 3, state)
        assert latest_step(d) == 3
        restored, step = restore(d, state)
        assert step == 3
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomicity_crash_midwrite():
    """A stale tmp dir (simulated crash) must not shadow the good ckpt."""
    cfg, hp, state = _tiny_state()
    with tempfile.TemporaryDirectory() as d:
        save(d, 1, state)
        os.makedirs(os.path.join(d, ".tmp-step_000002"))  # crashed save
        assert latest_step(d) == 1
        restored, step = restore(d, state)
        assert step == 1


def test_checkpoint_retention_gc():
    cfg, hp, state = _tiny_state()
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d, keep=2)
        for s in (1, 2, 3, 4):
            ck.save_sync(s, state)
        names = sorted(n for n in os.listdir(d) if n.startswith("step_"))
        assert names == ["step_000003", "step_000004"]


def test_checkpoint_async_overlap_and_wait():
    cfg, hp, state = _tiny_state()
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d, keep=3)
        ck.save_async(5, state)
        ck.wait()
        assert latest_step(d) == 5


def test_restore_shape_mismatch_raises():
    cfg, hp, state = _tiny_state()
    with tempfile.TemporaryDirectory() as d:
        save(d, 1, state)
        bad = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct((x.shape or (1,))[:1] + (99,),
                                           x.dtype)
            if hasattr(x, "shape") and len(x.shape) >= 1 else x,
            state,
        )
        with pytest.raises((ValueError, KeyError)):
            restore(d, bad)


# ---------------------------------------------------------------------------
# loop: watchdog, NaN guard, resume
# ---------------------------------------------------------------------------

def test_loop_resume_continues_from_checkpoint():
    cfg, hp, state = _tiny_state()
    step = jax.jit(make_train_step(cfg, hp))
    pipe = TokenPipeline(cfg.vocab, 32, 4, seed=0)
    with tempfile.TemporaryDirectory() as d:
        lc = LoopConfig(total_steps=4, checkpoint_dir=d,
                        checkpoint_every=2, log_every=100)
        run_loop(state, step, pipe.batches(), lc, log=lambda s: None)
        lc2 = LoopConfig(total_steps=8, checkpoint_dir=d,
                         checkpoint_every=2, log_every=100)
        r = run_loop(init_state(KEY, cfg, hp), step, pipe.batches(), lc2,
                     log=lambda s: None)
        assert r.resumed_from == 4
        assert int(r.state.step) == 8


def test_loop_watchdog_flags_straggler():
    cfg, hp, state = _tiny_state()
    inner = jax.jit(make_train_step(cfg, hp))
    # warm the jit cache so the first loop step isn't compile-dominated
    # (a cold first step would seed the EMA with seconds, hiding the
    # synthetic straggler)
    pipe_warm = TokenPipeline(cfg.vocab, 32, 4, seed=0)
    inner(state, pipe_warm.batch_at(0))
    calls = {"n": 0}

    def slow_step(st, b):
        calls["n"] += 1
        if calls["n"] == 9:
            time.sleep(1.0)  # synthetic straggler step
        return inner(st, b)

    pipe = TokenPipeline(cfg.vocab, 32, 4, seed=0)
    lc = LoopConfig(total_steps=10, log_every=100, watchdog_factor=3.0,
                    watchdog_warmup=3)
    r = run_loop(state, slow_step, pipe.batches(), lc, log=lambda s: None)
    assert r.straggler_steps >= 1


def test_loop_nan_guard_saves_postmortem():
    cfg, hp, state = _tiny_state()

    def nan_step(st, b):
        from repro.train.train_step import TrainState
        return TrainState(st.params, st.opt_state, st.step + 1, st.err), {
            "loss": jnp.asarray(float("nan"))
        }

    pipe = TokenPipeline(cfg.vocab, 32, 4, seed=0)
    with tempfile.TemporaryDirectory() as d:
        lc = LoopConfig(total_steps=5, checkpoint_dir=d, log_every=100)
        with pytest.raises(FloatingPointError):
            run_loop(state, nan_step, pipe.batches(), lc, log=lambda s: None)
        assert latest_step(d) is not None
