"""Categorical cofactor algebra: sparse group-by blocks vs one-hot oracle."""

import jax
import numpy as np
import pytest

from repro.core import (
    VERSIONS,
    linear_regression,
    solve_cofactor,
)
from repro.core.categorical import (
    SparseCounts,
    cat_cofactors_factorized,
    cat_cofactors_from_arrays,
    cat_cofactors_materialized,
    onehot_design_matrix,
)
from repro.core.distributed import (
    incremental_sharded_cat_cofactors,
    sharded_cat_cofactors,
)
from repro.core.relation import Relation
from repro.data.synthetic import favorita_like, figure1_schema

CONT = ["transactions", "onpromotion", "unit_sales"]
CAT = ["store_nbr", "item_nbr"]


@pytest.fixture(scope="module")
def favorita():
    return favorita_like(n_dates=8, n_stores=4, n_items=6, seed=3)


def _oracle_matrix(bundle, cont, cat):
    joined = bundle.store.materialize_join()
    doms = {c: bundle.store.attr_domain(c) for c in cat}
    x, names = onehot_design_matrix(joined, cont, cat, doms)
    z = np.concatenate([np.ones((x.shape[0], 1)), x], axis=1)
    return z.T @ z, ["intercept"] + names


def test_factorized_matches_onehot_oracle(favorita):
    cof = cat_cofactors_factorized(favorita.store, favorita.vorder, CONT, CAT)
    oracle, names = _oracle_matrix(favorita, CONT, CAT)
    np.testing.assert_allclose(cof.matrix(), oracle, rtol=1e-10, atol=1e-10)
    assert cof.column_names() == names
    # sparse representation is strictly smaller than the dense matrix
    assert cof.nnz() < cof.num_params**2


def test_materialized_and_kernel_paths_match(favorita):
    host = cat_cofactors_materialized(favorita.store, CONT, CAT)
    kern = cat_cofactors_materialized(
        favorita.store, CONT, CAT, use_kernel=True
    )
    oracle, _ = _oracle_matrix(favorita, CONT, CAT)
    np.testing.assert_allclose(host.matrix(), oracle, rtol=1e-10, atol=1e-10)
    # kernel path accumulates fp32
    np.testing.assert_allclose(kern.matrix(), oracle, rtol=1e-4, atol=1e-2)


def test_figure1_single_categorical():
    b = figure1_schema()
    cof = cat_cofactors_factorized(
        b.store, b.vorder, ["Inventory", "Sale"], ["L"]
    )
    oracle, _ = _oracle_matrix(b, ["Inventory", "Sale"], ["L"])
    np.testing.assert_allclose(cof.matrix(), oracle, rtol=1e-10, atol=1e-10)


def test_union_commutativity_with_domain_growth(favorita):
    """__add__ pads smaller domains — an append introducing unseen category
    ids must extend the blocks without disturbing existing entries."""
    joined = favorita.store.materialize_join()
    x = np.stack([joined.column(f).astype(float) for f in CONT], axis=1)
    ids = np.stack([joined.column(c).astype(np.int64) for c in CAT], axis=1)
    doms = {c: favorita.store.attr_domain(c) for c in CAT}
    half = x.shape[0] // 2
    small = {c: int(ids[:half, i].max()) + 1 for i, c in enumerate(CAT)}
    a = cat_cofactors_from_arrays(x[:half], ids[:half], CONT, CAT, small)
    b = cat_cofactors_from_arrays(x[half:], ids[half:], CONT, CAT, doms)
    whole = cat_cofactors_from_arrays(x, ids, CONT, CAT, doms)
    np.testing.assert_allclose(
        (a + b).matrix(), whole.matrix(), rtol=1e-12, atol=1e-12
    )


def test_sparse_counts_coalesce():
    coo = SparseCounts(
        np.array([0, 1, 0]), np.array([2, 0, 2]), np.array([1.0, 2.0, 3.0]),
        (2, 3),
    )
    total = coo + coo
    dense = total.to_dense()
    assert dense[0, 2] == 8.0 and dense[1, 0] == 4.0
    assert total.nnz == 2  # duplicates coalesced


def test_store_cat_cache_maintained_under_append(favorita):
    b = favorita_like(n_dates=8, n_stores=4, n_items=6, seed=3)
    cached = b.store.cat_cofactors(b.vorder, CONT, CAT)
    info = b.store.cache_info()
    assert info["cat_entries"] == 1
    rng = np.random.default_rng(0)
    n = 40
    delta = Relation.from_columns(
        "d",
        {
            "date": rng.integers(0, 8, n).astype(np.int32),
            "store_nbr": rng.integers(0, 4, n).astype(np.int32),
            "item_nbr": rng.integers(0, 6, n).astype(np.int32),
        },
        {
            "unit_sales": rng.normal(10, 2, n),
            "onpromotion": rng.integers(0, 2, n).astype(np.float64),
        },
    )
    b.store.append("SalesF", delta)
    maintained = b.store.cat_cofactors(b.vorder, CONT, CAT)
    fresh = b.store.cat_cofactors(b.vorder, CONT, CAT, refresh=True)
    np.testing.assert_allclose(
        maintained.matrix(), fresh.matrix(), rtol=1e-9, atol=1e-9
    )
    assert maintained.count == cached.count + n


def test_store_cat_cache_shared_delta_across_entries():
    """Multiple categorical entries over the same (vorder, backend) share
    one delta factorization — including entries whose cat order reverses a
    stored pair (exercises the project() transpose)."""
    b = favorita_like(n_dates=8, n_stores=4, n_items=6, seed=3)
    b.store.cat_cofactors(b.vorder, CONT, ["store_nbr", "item_nbr"])
    b.store.cat_cofactors(b.vorder, CONT[:2], ["item_nbr", "store_nbr"])
    b.store.cat_cofactors(b.vorder, ["unit_sales"], ["item_nbr"])
    assert b.store.cache_info()["cat_entries"] == 3
    rng = np.random.default_rng(5)
    n = 30
    delta = Relation.from_columns(
        "d",
        {
            "date": rng.integers(0, 8, n).astype(np.int32),
            "store_nbr": rng.integers(0, 4, n).astype(np.int32),
            "item_nbr": rng.integers(0, 6, n).astype(np.int32),
        },
        {
            "unit_sales": rng.normal(10, 2, n),
            "onpromotion": rng.integers(0, 2, n).astype(np.float64),
        },
    )
    b.store.append("SalesF", delta)
    for cont, cat in [
        (CONT, ["store_nbr", "item_nbr"]),
        (CONT[:2], ["item_nbr", "store_nbr"]),
        (["unit_sales"], ["item_nbr"]),
    ]:
        maintained = b.store.cat_cofactors(b.vorder, cont, cat)
        fresh = b.store.cat_cofactors(b.vorder, cont, cat, refresh=True)
        np.testing.assert_allclose(
            maintained.matrix(), fresh.matrix(), rtol=1e-9, atol=1e-9
        )


def test_store_cat_cache_invalidated_by_put(favorita):
    b = favorita_like(n_dates=6, n_stores=3, n_items=4, seed=1)
    b.store.cat_cofactors(b.vorder, CONT, CAT)
    assert b.store.cache_info()["cat_entries"] == 1
    b.store.put(b.store.get("SalesF"))  # arbitrary replacement
    assert b.store.cache_info()["cat_entries"] == 0


def test_linear_regression_categorical_matches_dense(favorita):
    feats = ["transactions", "store_nbr", "item_nbr"]
    res = linear_regression(
        favorita.store, favorita.vorder, feats, "unit_sales",
        config=VERSIONS["closed"], categorical=CAT, backend="numpy",
    )
    joined = favorita.store.materialize_join()
    doms = {c: favorita.store.attr_domain(c) for c in CAT}
    x, _ = onehot_design_matrix(joined, ["transactions"], CAT, doms)
    y = joined.column("unit_sales").astype(np.float64)
    z = np.concatenate([np.ones((x.shape[0], 1)), x, y[:, None]], axis=1)
    theta = solve_cofactor(z.T @ z, ridge=res.config.ridge)
    np.testing.assert_allclose(res.theta, theta, rtol=1e-8, atol=1e-8)
    assert res.names[-1] == "unit_sales"
    # warm path off the store cache agrees
    res2 = linear_regression(
        favorita.store, favorita.vorder, feats, "unit_sales",
        config=VERSIONS["closed"], categorical=CAT, use_cache=True,
    )
    np.testing.assert_allclose(res2.theta, res.theta, rtol=1e-9)


def test_sharded_cat_cofactors_match_host(favorita):
    joined = favorita.store.materialize_join()
    cont = ["transactions", "unit_sales"]
    x = np.stack([joined.column(f).astype(float) for f in cont], axis=1)
    ids = np.stack([joined.column(c).astype(np.int64) for c in CAT], axis=1)
    doms = {c: favorita.store.attr_domain(c) for c in CAT}
    mesh = jax.make_mesh((1,), ("data",))
    sh = sharded_cat_cofactors(x, ids, cont, CAT, doms, mesh)
    host = cat_cofactors_from_arrays(x, ids, cont, CAT, doms)
    np.testing.assert_allclose(sh.matrix(), host.matrix(), rtol=1e-4, atol=1e-2)
    # incremental fold reproduces the whole
    half = x.shape[0] // 2
    base = cat_cofactors_from_arrays(x[:half], ids[:half], cont, CAT, doms)
    inc = incremental_sharded_cat_cofactors(base, x[half:], ids[half:])
    np.testing.assert_allclose(inc.matrix(), host.matrix(), rtol=1e-9)
    # empty delta is a no-op
    same = incremental_sharded_cat_cofactors(
        inc, np.zeros((0, 2)), np.zeros((0, 2), dtype=np.int64)
    )
    assert same is inc


def test_incremental_fold_grows_domains(favorita):
    """A delta carrying category ids beyond the base domains must extend
    the blocks (zero-padded), not crash or silently drop rows."""
    joined = favorita.store.materialize_join()
    cont = ["transactions", "unit_sales"]
    x = np.stack([joined.column(f).astype(float) for f in cont], axis=1)
    ids = np.stack([joined.column(c).astype(np.int64) for c in CAT], axis=1)
    doms = {c: favorita.store.attr_domain(c) for c in CAT}
    base = cat_cofactors_from_arrays(x, ids, cont, CAT, doms)
    x_new = np.array([[100.0, 9.0], [200.0, 8.0]])
    ids_new = np.array(
        [[doms[CAT[0]] + 1, 0], [0, doms[CAT[1]]]], dtype=np.int64
    )
    grown = incremental_sharded_cat_cofactors(base, x_new, ids_new)
    big = {
        CAT[0]: doms[CAT[0]] + 2,
        CAT[1]: doms[CAT[1]] + 1,
    }
    whole = cat_cofactors_from_arrays(
        np.concatenate([x, x_new]), np.concatenate([ids, ids_new]),
        cont, CAT, big,
    )
    assert grown.domains == big
    np.testing.assert_allclose(
        grown.matrix(), whole.matrix(), rtol=1e-12, atol=1e-12
    )
    # too-small domains fail loudly on both explicit paths
    with pytest.raises(ValueError, match="outside domain"):
        cat_cofactors_from_arrays(x_new, ids_new, cont, CAT, doms)
    mesh = jax.make_mesh((1,), ("data",))
    with pytest.raises(ValueError, match="outside domain"):
        sharded_cat_cofactors(x_new, ids_new, cont, CAT, doms, mesh)
    # negative ids (the sharded path's internal padding sentinel) must be
    # rejected too: np.add.at would wrap them into the LAST category
    ids_neg = np.array([[-1, 0]], dtype=np.int64)
    with pytest.raises(ValueError, match="outside domain"):
        cat_cofactors_from_arrays(x_new[:1], ids_neg, cont, CAT, doms)
    with pytest.raises(ValueError, match="outside domain"):
        sharded_cat_cofactors(x_new[:1], ids_neg, cont, CAT, doms, mesh)


def test_grouped_view_sums_to_global(favorita):
    from repro.core import cofactors_factorized, grouped_cofactors_factorized

    cols = ["transactions", "unit_sales"]
    g = grouped_cofactors_factorized(
        favorita.store, favorita.vorder, cols, ["store_nbr"], backend="numpy"
    )
    tot = cofactors_factorized(
        favorita.store, favorita.vorder, cols, backend="numpy"
    )
    np.testing.assert_allclose(g.count.sum(), tot.count)
    np.testing.assert_allclose(g.lin.sum(0), tot.lin, rtol=1e-10)
    np.testing.assert_allclose(g.quad.sum(0), tot.quad, rtol=1e-10)


def test_random_schemas_sparse_equals_onehot():
    """Deterministic mirror of the hypothesis properties in
    test_property.py (which need the optional hypothesis dependency):
    fused single-pass categorical cofactors == the per-pass path to 1e-12
    == the one-hot Gram oracle on random acyclic snowflakes."""
    from repro.core.categorical import cat_cofactors_per_pass
    from repro.data.synthetic import random_acyclic_schema

    for seed in range(10):
        b = random_acyclic_schema(seed, n_branches=(seed % 3) + 1)
        cat = ["k0"] + [f"k{i + 1}" for i in range(len(b.features) // 2)]
        cont = b.features + [b.label]
        stats = {}
        sparse = cat_cofactors_factorized(
            b.store, b.vorder, cont, cat, backend="numpy", stats=stats
        )
        assert stats["passes"] == 1
        per_pass = cat_cofactors_per_pass(
            b.store, b.vorder, cont, cat, backend="numpy"
        )
        np.testing.assert_allclose(
            sparse.matrix(), per_pass.matrix(), rtol=1e-12, atol=1e-12
        )
        joined = b.store.materialize_join()
        doms = {c: b.store.attr_domain(c) for c in cat}
        x, _ = onehot_design_matrix(joined, cont, cat, doms)
        z = np.concatenate([np.ones((x.shape[0], 1)), x], axis=1)
        np.testing.assert_allclose(
            sparse.matrix(), z.T @ z, rtol=1e-9, atol=1e-9
        )


def test_group_by_feature_overlap_rejected(favorita):
    from repro.core import FactorizedEngine

    with pytest.raises(ValueError, match="both a feature and"):
        FactorizedEngine(
            favorita.store, favorita.vorder, ["store_nbr"],
            group_by=["store_nbr"],
        )


# ---------------------------------------------------------------------------
# Fused multi-output plan (single-pass engine)
# ---------------------------------------------------------------------------

def test_fused_plan_is_single_pass_regardless_of_cat_count(favorita):
    """Acceptance criterion: ONE engine traversal however many categorical
    attributes (and pairs) the batch carries — audited by the engine's
    pass counter threaded out through ``stats``."""
    from repro.core.categorical import cat_cofactors_per_pass

    for cat in (["store_nbr"], ["store_nbr", "item_nbr"],
                ["store_nbr", "item_nbr", "date"]):
        stats = {}
        fused = cat_cofactors_factorized(
            favorita.store, favorita.vorder, CONT, cat, stats=stats
        )
        assert stats["passes"] == 1, (cat, stats)
        per_pass = cat_cofactors_per_pass(
            favorita.store, favorita.vorder, CONT, cat
        )
        np.testing.assert_allclose(
            fused.matrix(), per_pass.matrix(), rtol=1e-12, atol=1e-12
        )


def test_fused_plan_shares_subtrees(favorita):
    """node_visits must grow far slower than the per-pass path's
    O(passes × nodes): distinct (node, live-subset) views are the unit of
    work, and subtrees below all referenced attributes are shared."""
    from repro.core import AggregateQuery, FactorizedEngine

    n_nodes = 1 + len(favorita.vorder.variables()) + len(
        favorita.vorder.relations()
    )
    cat = ["store_nbr", "item_nbr", "date"]
    queries = [AggregateQuery("base", (), 2)]
    queries += [AggregateQuery(f"g{c}", (c,), 1) for c in cat]
    queries += [
        AggregateQuery(f"p{i}{j}", (cat[i], cat[j]), 0)
        for i in range(3) for j in range(i + 1, 3)
    ]
    eng = FactorizedEngine(
        favorita.store, favorita.vorder, CONT, backend="numpy"
    )
    eng.run_batch(queries)
    assert eng.passes == 1
    per_pass_visits = len(queries) * n_nodes
    assert eng.node_visits < per_pass_visits
    # re-running the same batch is a second traversal for the pass counter
    # even when the persistent view cache answers every node (the
    # cross-batch reuse itself is audited in tests/test_view_cache.py)
    eng.run_batch(queries)
    assert eng.passes == 2


def test_engine_pass_counters_on_store():
    b = favorita_like(n_dates=6, n_stores=3, n_items=4, seed=2)
    b.store.cat_cofactors(b.vorder, CONT, CAT)
    info = b.store.cache_info()
    assert info["cat_passes"] == 1
    b.store.cat_cofactors(b.vorder, CONT, CAT)  # cache hit: no new pass
    assert b.store.cache_info()["cat_passes"] == 1


def test_fused_degree_trimming_matches_full(favorita):
    """Degree-0/1 queries share views with the degree-2 base query — their
    trimmed blocks must equal the separate full grouped evaluation."""
    from repro.core import AggregateQuery, FactorizedEngine
    from repro.core import grouped_cofactors_factorized

    cols = ["transactions", "unit_sales"]
    eng = FactorizedEngine(
        favorita.store, favorita.vorder, cols, backend="numpy"
    )
    out = eng.run_batch(
        [
            AggregateQuery("base", (), 2),
            AggregateQuery("g", ("store_nbr",), 1),
            AggregateQuery("p", ("store_nbr", "item_nbr"), 0),
        ]
    )
    full = grouped_cofactors_factorized(
        favorita.store, favorita.vorder, cols, ["store_nbr"], backend="numpy"
    )
    g = out["g"]
    order = np.argsort(g.ids("store_nbr"))
    forder = np.argsort(full.ids("store_nbr"))
    np.testing.assert_allclose(
        g.count[order], full.count[forder], rtol=0, atol=0
    )
    perm = [g.features.index(f) for f in cols]
    np.testing.assert_allclose(
        g.lin[order][:, perm], full.lin[forder], rtol=1e-12
    )
    assert g.quad is None  # degree 1 never materializes [N, k, k]
    p = out["p"]
    assert p.lin is None and p.quad is None  # degree 0: counts only
    np.testing.assert_allclose(p.count.sum(), out["base"].count[0])


def test_many_categorical_attributes_fused():
    """A fact table with 12 categorical keys: the fused plan still runs in
    ONE pass, wide-key grouping does not overflow int64 (group_key
    densification), and the result matches the one-hot oracle."""
    from repro.data.synthetic import many_cat_schema

    b = many_cat_schema(n_cat=12, domain=7, n_rows=150, seed=1)
    cat = [f"c{i}" for i in range(12)]
    stats = {}
    fused = cat_cofactors_factorized(
        b.store, b.vorder, ["x", "y"], cat, stats=stats
    )
    assert stats["passes"] == 1
    joined = b.store.materialize_join()
    doms = {c: b.store.attr_domain(c) for c in cat}
    x, _ = onehot_design_matrix(joined, ["x", "y"], cat, doms)
    z = np.concatenate([np.ones((x.shape[0], 1)), x], axis=1)
    np.testing.assert_allclose(fused.matrix(), z.T @ z, rtol=1e-9, atol=1e-9)
