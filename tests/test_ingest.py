"""Streaming ingest: the pending-delta log behind lazy maintenance.

The contract this PR is held to:

* **O(delta) writes** — ``Store.append`` under the default
  ``maintenance="lazy"`` touches no view-cache or cofactor entry: zero
  engine passes and zero node visits on the write path, counter-audited,
  independent of how many queries are warm.
* **Lazy ≡ eager** — any interleaving of appends, reads, puts and FD
  churn produces the same cached answers under lazy and eager
  maintenance, and both equal an uncached recompute at 1e-12.
* **Bounded staleness** — pending rows never exceed the compaction
  threshold; drains fold the whole stack in one pass; a drain that
  raises invalidates rather than half-updates.
* **Snapshot currency** — a snapshot taken with deltas pending reads the
  already-published rows; the later drain (which bumps no version) does
  not invalidate it.
"""

import numpy as np
import pytest

import repro.core.categorical as catmod
from repro.core.categorical import cat_cofactors_factorized
from repro.core.relation import Relation
from repro.core.store import Store
from repro.data.synthetic import many_cat_schema, random_acyclic_schema
from repro.serve import FactorizedService

CONT = ["x", "y"]


def _delta_for(rel: Relation, rng, n_rows: int, grow: bool = False) -> Relation:
    keys = {}
    for i, (a, _col) in enumerate(rel.keys.items()):
        dom = int(rel.domains[a])
        ids = rng.integers(0, dom, n_rows).astype(np.int32)
        if grow and i == 0 and n_rows:
            ids[0] = dom  # one id past the current dictionary
        keys[a] = ids
    values = {a: rng.normal(0, 2.0, n_rows) for a in rel.values}
    return Relation.from_columns("delta", keys, values)


def _clone(store: Store, **kwargs) -> Store:
    return Store([store.get(n) for n in store.names()], **kwargs)


# ---------------------------------------------------------------------------
# O(delta) write path: counter-audited, independent of cache population
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_warm", [0, 1, 3])
def test_append_write_path_zero_visits(n_warm):
    """The write path folds nothing no matter how many queries are warm —
    the latency-regression guard for bounded-latency ingest."""
    b = many_cat_schema(n_cat=3, domain=8, n_rows=300, seed=7)
    cat = [f"c{i}" for i in range(3)]
    for k in range(n_warm):  # population level: k distinct cached queries
        b.store.cat_cofactors(b.vorder, CONT, cat[: k + 1])
    if n_warm:
        b.store.cofactors(b.vorder, CONT, backend="numpy")
    vc = b.store.view_cache
    b.store.reset_counters()
    hits, misses = vc.hits, vc.misses

    rng = np.random.default_rng(1)
    b.store.append("Fact", _delta_for(b.store.get("Fact"), rng, 40))
    b.store.append("Fact", _delta_for(b.store.get("Fact"), rng, 25))

    assert b.store.passes == 0 and b.store.node_visits == 0
    assert b.store.cat_passes == 0 and b.store.cat_node_visits == 0
    assert (vc.hits, vc.misses) == (hits, misses)  # cache never probed
    info = b.store.cache_info()
    assert info["maintenance"] == "lazy"
    assert info["pending_relations"] == 1
    assert info["pending_rows"] == 65 and info["pending_appends"] == 2


def test_maintenance_mode_validated():
    with pytest.raises(ValueError, match="maintenance"):
        Store(maintenance="sometimes")


# ---------------------------------------------------------------------------
# Drain mechanics: stacked deltas, one pass, idempotent flush
# ---------------------------------------------------------------------------

def test_stacked_appends_drain_in_one_pass():
    b = many_cat_schema(n_cat=2, domain=8, n_rows=250, seed=8)
    cat = ["c0", "c1"]
    warm = b.store.cat_cofactors(b.vorder, CONT, cat)
    rng = np.random.default_rng(2)
    for n in (10, 20, 15):
        b.store.append("Fact", _delta_for(b.store.get("Fact"), rng, n))

    stats = b.store.flush()
    assert stats == {"relations": 1, "rows": 45, "appends": 3}
    info = b.store.cache_info()
    assert info["pending_rows"] == 0 and info["pending_relations"] == 0
    assert info["drains"] == 1 and info["drained_rows"] == 45

    assert b.store.flush() == {"relations": 0, "rows": 0, "appends": 0}
    assert b.store.cache_info()["drains"] == 1  # no-op flush, no drain

    out = b.store.cat_cofactors(b.vorder, CONT, cat)  # folded, not rebuilt
    ref = cat_cofactors_factorized(
        b.store, b.vorder, CONT, cat, use_view_cache=False
    )
    scale = max(1.0, float(np.abs(ref.matrix()).max()))
    np.testing.assert_allclose(
        out.matrix(), ref.matrix(), rtol=1e-12, atol=1e-12 * scale
    )
    assert out.matrix().shape == warm.matrix().shape


def test_flush_names_scope_hint():
    """A flush scoped to relations with nothing pending is a no-op; any
    overlap drains the WHOLE log (partial drains would half-fold entries
    spanning several pending relations)."""
    b = many_cat_schema(n_cat=2, domain=8, n_rows=200, seed=9)
    rng = np.random.default_rng(3)
    b.store.append("Fact", _delta_for(b.store.get("Fact"), rng, 12))
    # small dim delta: stays under the 0.5 compaction ratio of 8 base rows
    b.store.append("Dim0", _delta_for(b.store.get("Dim0"), rng, 3))

    assert b.store.flush(["Dim1"])["rows"] == 0  # disjoint: no drain
    assert b.store.cache_info()["pending_rows"] == 15
    assert b.store.flush(["Dim0"]) == {
        "relations": 2, "rows": 15, "appends": 2,
    }
    assert b.store.cache_info()["pending_rows"] == 0


def test_zero_row_append_keeps_entries_current():
    """An empty delta bumps the version but moves no watermark: warm
    entries stay valid and the next read recomputes nothing."""
    b = many_cat_schema(n_cat=2, domain=8, n_rows=200, seed=10)
    b.store.cat_cofactors(b.vorder, CONT, ["c0"])
    rel = b.store.get("Fact")
    empty = _delta_for(rel, np.random.default_rng(4), 0)
    v = b.store.version
    b.store.append("Fact", empty)
    assert b.store.version == v + 1
    assert not b.store.cache_info()["pending_appends"]  # nothing logged
    before = b.store.cat_passes
    b.store.cat_cofactors(b.vorder, CONT, ["c0"])
    assert b.store.cat_passes == before  # served from the entry


def test_compaction_bounds_pending_rows():
    """Past the absolute threshold the log is compacted — covering
    entries invalidated, pending cleared — so retrain staleness (and the
    drain debt) is bounded; the next read recomputes correctly."""
    b = many_cat_schema(n_cat=2, domain=8, n_rows=200, seed=11)
    store = _clone(b.store, compact_rows=30)
    store.cat_cofactors(b.vorder, CONT, ["c0"])
    rng = np.random.default_rng(5)
    store.append("Fact", _delta_for(store.get("Fact"), rng, 20))
    assert store.cache_info()["compactions"] == 0
    store.append("Fact", _delta_for(store.get("Fact"), rng, 20))  # 40 > 30
    info = store.cache_info()
    assert info["compactions"] == 1 and info["pending_rows"] == 0
    out = store.cat_cofactors(b.vorder, CONT, ["c0"])
    ref = cat_cofactors_factorized(
        store, b.vorder, CONT, ["c0"], use_view_cache=False
    )
    np.testing.assert_allclose(out.matrix(), ref.matrix(), rtol=1e-12,
                               atol=1e-9)


# ---------------------------------------------------------------------------
# Lazy ≡ eager under random interleavings (deterministic property)
# ---------------------------------------------------------------------------

def _assert_modes_agree(lazy, eager, vorder, cont, cat):
    a = lazy.cat_cofactors(vorder, cont, cat)  # read barrier drains
    c = eager.cat_cofactors(vorder, cont, cat)
    fresh = cat_cofactors_factorized(
        lazy, vorder, cont, cat, use_view_cache=False
    )
    scale = max(1.0, float(np.abs(fresh.matrix()).max()))
    tol = dict(rtol=1e-12, atol=1e-12 * scale)
    np.testing.assert_allclose(a.matrix(), fresh.matrix(), **tol)
    np.testing.assert_allclose(c.matrix(), fresh.matrix(), **tol)


def _apply_everywhere(stores, op: int, rng) -> None:
    """One mutation applied identically to every store (data states are
    always equal across maintenance modes — only cache states differ)."""
    lead = stores[0]
    names = lead.names()
    name = names[op % len(names)]
    rel = lead.get(name)
    kind = (op // len(names)) % 3
    if kind == 0:  # append (occasionally with unseen ids)
        delta = _delta_for(rel, rng, int(rng.integers(1, 8)),
                           grow=bool(op % 2))
        for s in stores:
            s.append(name, delta)
    elif kind == 1:  # put: replace with a perturbed copy
        values = {
            a: c + rng.normal(0, 0.1, len(c)) for a, c in rel.values.items()
        }
        put = Relation(rel.name, dict(rel.keys), values, dict(rel.domains))
        for s in stores:
            s.put(put)
    else:  # FD churn
        drop = None
        for s in stores:
            s.infer_fds()
            fds = s.fds()
            if drop is None and fds:
                drop = fds[int(rng.integers(0, len(fds)))]
        if drop is not None:
            for s in stores:
                s.drop_fd(drop.lhs, drop.rhs)


def test_lazy_equals_eager_interleavings_deterministic():
    for seed in range(5):
        b = random_acyclic_schema(seed, n_branches=(seed % 3) + 1)
        lazy = b.store  # default maintenance
        assert lazy.maintenance == "lazy"
        eager = _clone(lazy, maintenance="eager")
        cat = ["k0"] + [f"k{i + 1}" for i in range(len(b.features) // 2)]
        cont = b.features + [b.label]
        rng = np.random.default_rng(seed)
        _assert_modes_agree(lazy, eager, b.vorder, cont, cat)
        for _op in range(5):
            _apply_everywhere([lazy, eager], int(rng.integers(0, 30)), rng)
            _assert_modes_agree(lazy, eager, b.vorder, cont, cat)


# ---------------------------------------------------------------------------
# Snapshot currency across pending deltas and drains
# ---------------------------------------------------------------------------

def test_snapshot_with_pending_deltas_reads_published_rows():
    b = many_cat_schema(n_cat=2, domain=8, n_rows=200, seed=12)
    rng = np.random.default_rng(6)
    b.store.cat_cofactors(b.vorder, CONT, ["c0"])
    b.store.append("Fact", _delta_for(b.store.get("Fact"), rng, 30))

    snap = b.store.snapshot()  # taken with 30 rows pending
    assert snap.is_current
    assert b.store.cache_info()["pending_rows"] == 30
    ref = cat_cofactors_factorized(
        _clone(b.store), b.vorder, CONT, ["c0"], use_view_cache=False
    )
    # the snapshot read's barrier drains the live log; the drain bumps no
    # version, so the snapshot stays current through its own read
    out = snap.cat_cofactors(b.vorder, CONT, ["c0"])
    np.testing.assert_allclose(out.matrix(), ref.matrix(), rtol=1e-12,
                               atol=1e-9)
    assert b.store.cache_info()["pending_rows"] == 0
    assert snap.is_current
    again = snap.cat_cofactors(b.vorder, CONT, ["c0"])
    scale = max(1.0, float(np.abs(ref.matrix()).max()))
    np.testing.assert_allclose(
        again.matrix(), ref.matrix(), rtol=1e-12, atol=1e-12 * scale
    )

    b.store.append("Fact", _delta_for(b.store.get("Fact"), rng, 5))
    assert not snap.is_current  # a real mutation does retire it
    assert snap.flush() == {"relations": 0, "rows": 0, "appends": 0}


def test_snapshot_flush_forwards_while_current():
    b = many_cat_schema(n_cat=2, domain=8, n_rows=150, seed=13)
    b.store.append(
        "Fact", _delta_for(b.store.get("Fact"), np.random.default_rng(7), 9)
    )
    snap = b.store.snapshot()
    assert snap.flush()["rows"] == 9  # forwarded to the live store
    assert b.store.cache_info()["pending_rows"] == 0


# ---------------------------------------------------------------------------
# Drain exception safety (the lazy twin of the poisoned-delta test)
# ---------------------------------------------------------------------------

def test_poisoned_drain_invalidates_instead_of_corrupting(monkeypatch):
    """A fold that raises at DRAIN time (the append already published the
    rows) must invalidate every covering entry and clear the log — the
    reader sees the error, the next read recomputes coherently."""
    b = many_cat_schema(n_cat=2, domain=8, n_rows=250, seed=14)
    b.store.cofactors(b.vorder, CONT, backend="numpy")
    b.store.cat_cofactors(b.vorder, CONT, ["c0"])
    rng = np.random.default_rng(8)
    b.store.append("Fact", _delta_for(b.store.get("Fact"), rng, 15))
    rows_after = b.store.get("Fact").num_rows

    def boom(*a, **k):
        raise RuntimeError("poisoned drain")

    # the plain cofactor fold mutates its entry BEFORE the categorical
    # fold raises — exactly the half-updated hazard
    monkeypatch.setattr(catmod, "cat_cofactors_factorized", boom)
    with pytest.raises(RuntimeError, match="poisoned drain"):
        b.store.flush()
    monkeypatch.undo()

    assert b.store.get("Fact").num_rows == rows_after  # rows stay published
    info = b.store.cache_info()
    assert info["entries"] == 0 and info["cat_entries"] == 0
    assert info["pending_rows"] == 0  # log cleared, not wedged
    out = b.store.cat_cofactors(b.vorder, CONT, ["c0"])
    ref = cat_cofactors_factorized(
        b.store, b.vorder, CONT, ["c0"], use_view_cache=False
    )
    np.testing.assert_allclose(out.matrix(), ref.matrix(), rtol=1e-12,
                               atol=1e-9)


# ---------------------------------------------------------------------------
# Service: idle-window folding between drain cycles
# ---------------------------------------------------------------------------

def _svc_schema(seed=20):
    b = many_cat_schema(n_cat=2, domain=8, n_rows=200, seed=seed)
    return b


def test_service_flush_policy_validated():
    b = _svc_schema()
    with pytest.raises(ValueError, match="flush_policy"):
        FactorizedService(b.store, flush_policy="eventually")


def test_service_idle_policy_folds_after_writes():
    """Default policy: a cycle that ends with no queued reads folds the
    pending writes, so the next read starts warm with nothing pending."""
    b = _svc_schema(21)
    svc = FactorizedService(b.store)
    rng = np.random.default_rng(9)
    svc.cofactors("a", b.vorder, CONT)
    svc.drain()
    svc.append("w", "Fact", _delta_for(b.store.get("Fact"), rng, 12))
    svc.drain()  # write lands, queue empty afterwards -> idle fold
    assert b.store.cache_info()["pending_rows"] == 0
    b.store.reset_counters()
    svc.cofactors("a", b.vorder, CONT)
    svc.drain()
    assert b.store.node_visits == 0  # idle fold kept the entry warm


def test_service_never_policy_defers_until_explicit_flush():
    b = _svc_schema(22)
    svc = FactorizedService(b.store, flush_policy="never")
    rng = np.random.default_rng(10)
    svc.append("w", "Fact", _delta_for(b.store.get("Fact"), rng, 8))
    svc.drain()
    assert b.store.cache_info()["pending_rows"] == 8
    svc.flush()  # the explicit idle-window pass
    assert b.store.cache_info()["pending_rows"] == 0


def test_service_counters_stay_exact_across_flush_policies():
    """Per-tenant shares still sum to store totals when drain work happens
    inside service-triggered folds (charged to the tenants that wrote)."""
    for policy in ("idle", "always", "never"):
        b = _svc_schema(23)
        svc = FactorizedService(b.store, flush_policy=policy)
        rng = np.random.default_rng(11)
        svc.cofactors("a", b.vorder, CONT)
        svc.train("c", b.vorder, ["x"], "y")
        svc.drain()
        svc.append("w", "Fact", _delta_for(b.store.get("Fact"), rng, 10))
        svc.cofactors("b", b.vorder, CONT)
        svc.run()
        if policy == "never":
            svc.flush()
        info = svc.cache_info()
        tenants = info["tenants"].values()
        vc = b.store.view_cache
        assert sum(t["passes"] for t in tenants) == info["passes"]
        assert (
            sum(t["node_visits"] for t in tenants) == info["node_visits"]
        )
        assert sum(t["vc_hits"] for t in tenants) == vc.hits
        assert sum(t["vc_misses"] for t in tenants) == vc.misses
        assert b.store.cache_info()["pending_rows"] == 0
