"""Incremental cofactor maintenance: Store.append, the cofactor cache,
streaming/grouped accumulation, and the warm-retrain path.

The correctness anchor everywhere is Prop. 4.1 union commutativity: joins
distribute over union, so the cofactors after an append must equal a
from-scratch recompute — the delta path is checked against that oracle at
fp64 tolerance.
"""

import numpy as np
import pytest

from repro.core import (
    VERSIONS,
    cofactors_factorized,
    cofactors_grouped,
    cofactors_materialized,
    cofactors_streaming,
    design_matrix,
    compute_scale_factors,
    linear_regression,
)
from repro.core.distributed import incremental_sharded_cofactors
from repro.core.relation import Relation
from repro.data.synthetic import favorita_like, figure1_schema

RNG = np.random.default_rng(7)


def _sales_delta(n_rows, n_dates=8, n_stores=4, n_items=6, rng=RNG):
    return Relation.from_columns(
        "delta",
        {
            "date": rng.integers(0, n_dates, n_rows).astype(np.int32),
            "store_nbr": rng.integers(0, n_stores, n_rows).astype(np.int32),
            "item_nbr": rng.integers(0, n_items, n_rows).astype(np.int32),
        },
        {
            "unit_sales": rng.normal(10, 2, n_rows),
            "onpromotion": rng.integers(0, 2, n_rows).astype(np.float64),
        },
    )


@pytest.fixture()
def favorita():
    return favorita_like(n_dates=8, n_stores=4, n_items=6, seed=3)


# ---------------------------------------------------------------------------
# Store.append + cache maintenance
# ---------------------------------------------------------------------------

def test_append_merges_rows_and_domains(favorita):
    store = favorita.store
    before = store.get("SalesF").num_rows
    merged = store.append("SalesF", _sales_delta(13))
    assert merged.num_rows == before + 13
    assert store.get("SalesF").num_rows == before + 13
    # domains survive the merge (delta ids are within existing domains here)
    assert store.get("SalesF").domains["date"] == 8


def test_append_requires_same_attributes(favorita):
    bad = Relation.from_columns("d", {"date": [0]}, {"unit_sales": [1.0]})
    with pytest.raises(ValueError):
        favorita.store.append("SalesF", bad)
    with pytest.raises(KeyError):
        favorita.store.append("NoSuchRelation", _sales_delta(1))


def test_append_delta_equals_scratch_recompute(favorita):
    """Acceptance criterion: the delta path == from-scratch at fp64 tol."""
    b = favorita
    cols = b.features + [b.label]
    b.store.cofactors(b.vorder, cols, backend="numpy")  # seed the cache
    for n in (17, 5, 29):  # repeated appends fold repeatedly
        b.store.append("SalesF", _sales_delta(n))
    warm = b.store.cofactors(b.vorder, cols, backend="numpy")
    cold = cofactors_factorized(b.store, b.vorder, cols, backend="numpy")
    np.testing.assert_allclose(
        warm.matrix(), cold.matrix(), rtol=1e-12, atol=1e-9
    )


def test_append_to_dimension_relation_maintains_cache(favorita):
    """Appending to a *dimension* relation multiplies out differently than a
    fact append — the delta join must still be exact."""
    b = favorita
    cols = b.features + [b.label]
    b.store.cofactors(b.vorder, cols, backend="numpy")
    # a second transactions batch for existing (date, store) pairs
    delta = Relation.from_columns(
        "d",
        {"date": [0, 1, 2], "store_nbr": [0, 1, 2]},
        {"transactions": [111.0, 222.0, 333.0]},
    )
    b.store.append("Transactions", delta)
    warm = b.store.cofactors(b.vorder, cols, backend="numpy")
    cold = cofactors_factorized(b.store, b.vorder, cols, backend="numpy")
    np.testing.assert_allclose(
        warm.matrix(), cold.matrix(), rtol=1e-12, atol=1e-9
    )


def test_interleaved_appends_to_different_relations(favorita):
    """ΔR then ΔS: the second delta must see the already-merged first one."""
    b = favorita
    cols = b.features + [b.label]
    b.store.cofactors(b.vorder, cols, backend="numpy")
    b.store.append("SalesF", _sales_delta(11))
    b.store.append(
        "Transactions",
        Relation.from_columns(
            "d",
            {"date": [3], "store_nbr": [3]},
            {"transactions": [999.0]},
        ),
    )
    b.store.append("SalesF", _sales_delta(4))
    warm = b.store.cofactors(b.vorder, cols, backend="numpy")
    cold = cofactors_factorized(b.store, b.vorder, cols, backend="numpy")
    np.testing.assert_allclose(
        warm.matrix(), cold.matrix(), rtol=1e-12, atol=1e-9
    )


def test_cache_hit_and_put_invalidation(favorita):
    b = favorita
    cols = b.features + [b.label]
    c1 = b.store.cofactors(b.vorder, cols, backend="numpy")
    assert b.store.cache_info()["entries"] == 1
    c2 = b.store.cofactors(b.vorder, cols, backend="numpy")
    assert c2 is c1  # cache hit, no recompute
    # overwriting a covered relation invalidates (arbitrary mutation)
    b.store.put(b.store.get("Oil"))
    assert b.store.cache_info()["entries"] == 0
    c3 = b.store.cofactors(b.vorder, cols, backend="numpy")
    np.testing.assert_allclose(c3.matrix(), c1.matrix(), rtol=1e-12)


def test_put_unrelated_relation_keeps_cache(favorita):
    b = favorita
    cols = b.features + [b.label]
    b.store.cofactors(b.vorder, cols, backend="numpy")
    b.store.put(
        Relation.from_columns("Unrelated", {"zz": [0]}, {"w": [1.0]})
    )
    assert b.store.cache_info()["entries"] == 1


def test_cache_keyed_by_features_and_backend(favorita):
    b = favorita
    cols = b.features + [b.label]
    b.store.cofactors(b.vorder, cols, backend="numpy")
    b.store.cofactors(b.vorder, cols[:2], backend="numpy")
    b.store.cofactors(b.vorder, cols, backend="jax")
    assert b.store.cache_info()["entries"] == 3


def test_append_maintains_all_cache_entries(favorita):
    """Multiple live entries (feature subsets share one delta factorization
    via project) must all stay exact after an append."""
    b = favorita
    cols = b.features + [b.label]
    b.store.cofactors(b.vorder, cols, backend="numpy")
    b.store.cofactors(b.vorder, cols[:2], backend="numpy")
    b.store.append("SalesF", _sales_delta(9))
    for feats in (cols, cols[:2]):
        warm = b.store.cofactors(b.vorder, feats, backend="numpy")
        cold = cofactors_factorized(b.store, b.vorder, feats, backend="numpy")
        np.testing.assert_allclose(
            warm.matrix(), cold.matrix(), rtol=1e-12, atol=1e-9
        )


def test_column_moments_maintained_under_append(favorita):
    """Scale factors from maintained moments == recompute on a fresh store."""
    from repro.core.store import Store

    b = favorita
    for f in b.features + [b.label]:
        b.store.column_moments(f)  # seed the moments cache
    b.store.append("SalesF", _sales_delta(21))
    factors = compute_scale_factors(b.store, b.features, b.label)
    fresh = Store(b.store.relations())  # same data, no caches
    expect = compute_scale_factors(fresh, b.features, b.label)
    for col in b.features + [b.label]:
        np.testing.assert_allclose(factors.avg[col], expect.avg[col],
                                   rtol=1e-12)
        np.testing.assert_allclose(factors.max[col], expect.max[col],
                                   rtol=1e-12)
    # put() drops the affected columns' moments
    b.store.put(b.store.get("SalesF"))
    factors2 = compute_scale_factors(b.store, b.features, b.label)
    np.testing.assert_allclose(
        factors2.avg[b.label], expect.avg[b.label], rtol=1e-12
    )


# ---------------------------------------------------------------------------
# warm retrain (regression wiring) + lazy rescale
# ---------------------------------------------------------------------------

def test_warm_retrain_after_append_matches_cold(favorita):
    b = favorita
    cfg = VERSIONS["closed"]  # deterministic solver: exact comparison
    linear_regression(
        b.store, b.vorder, b.features, b.label, config=cfg,
        backend="numpy", use_cache=True,
    )
    b.store.append("SalesF", _sales_delta(25))
    warm = linear_regression(
        b.store, b.vorder, b.features, b.label, config=cfg,
        backend="numpy", use_cache=True,
    )
    cold = linear_regression(
        b.store, b.vorder, b.features, b.label, config=cfg, backend="numpy"
    )
    np.testing.assert_allclose(warm.theta, cold.theta, rtol=1e-8, atol=1e-8)


def test_rescale_matches_engine_scaled_compute(favorita):
    b = favorita
    cols = b.features + [b.label]
    factors = compute_scale_factors(b.store, b.features, b.label)
    direct = cofactors_factorized(
        b.store, b.vorder, cols, backend="numpy", scale=factors
    )
    lazy = cofactors_factorized(
        b.store, b.vorder, cols, backend="numpy"
    ).rescale(factors)
    np.testing.assert_allclose(
        lazy.matrix(), direct.matrix(), rtol=1e-9, atol=1e-9
    )


# ---------------------------------------------------------------------------
# streaming / grouped accumulation
# ---------------------------------------------------------------------------

def test_streaming_equals_oracle(favorita):
    b = favorita
    cols = b.features + [b.label]
    joined = b.store.materialize_join()
    z = design_matrix(joined, cols)
    for chunk_rows in (1, 7, 64, 10_000):  # incl. single-row and one-shot
        stream = cofactors_streaming(z, cols, chunk_rows=chunk_rows)
        np.testing.assert_allclose(stream.count, z.shape[0])
        np.testing.assert_allclose(
            stream.lin, z.sum(0), rtol=5e-4, atol=1e-2
        )
        np.testing.assert_allclose(
            stream.quad, z.T @ z, rtol=5e-4, atol=1e-2
        )


def test_streaming_materialized_path(favorita):
    b = favorita
    cols = b.features + [b.label]
    one_shot = cofactors_materialized(b.store, cols)
    streamed = cofactors_materialized(b.store, cols, chunk_rows=19)
    np.testing.assert_allclose(
        streamed.matrix(), one_shot.matrix(), rtol=5e-4, atol=1e-2
    )


def test_streaming_empty_and_iterable_inputs():
    cols = ["a", "b"]
    empty = cofactors_streaming(iter(()), cols)
    assert empty.count == 0.0
    chunks = [RNG.normal(size=(5, 2)), RNG.normal(size=(3, 2))]
    cof = cofactors_streaming(iter(chunks), cols)
    z = np.concatenate(chunks, 0)
    np.testing.assert_allclose(cof.quad, z.T @ z, rtol=5e-4, atol=1e-3)
    with pytest.raises(ValueError):
        cofactors_streaming(z, cols)  # matrix input needs chunk_rows
    with pytest.raises(ValueError):
        cofactors_streaming(z, cols, chunk_rows=-5)  # must not fold 0 chunks
    with pytest.raises(ValueError):  # wrong width must not broadcast
        cofactors_streaming(iter([RNG.normal(size=(4, 1))]), cols)


def test_grouped_sums_to_global():
    z = RNG.normal(size=(50, 3))
    seg = RNG.integers(0, 6, 50)
    groups = cofactors_grouped(z, seg, 6, ["a", "b", "c"])
    total = groups[0]
    for g in groups[1:]:
        total = total + g
    np.testing.assert_allclose(total.count, 50)
    np.testing.assert_allclose(total.quad, z.T @ z, rtol=5e-4, atol=1e-2)
    oracle = cofactors_grouped(z, seg, 6, ["a", "b", "c"], use_kernel=False)
    for got, exp in zip(groups, oracle):
        np.testing.assert_allclose(got.quad, exp.quad, rtol=5e-4, atol=1e-2)


def test_grouped_out_of_range_segments_dropped_on_both_paths():
    """Negative / too-large segment ids contribute to no group, matching the
    kernel's zero-one-hot-row semantics."""
    z = RNG.normal(size=(6, 2))
    seg = np.array([0, -1, 1, 5, 0, 2])  # -1 and 5 out of range for G=3
    feats = ["a", "b"]
    kern = cofactors_grouped(z, seg, 3, feats, use_kernel=True)
    host = cofactors_grouped(z, seg, 3, feats, use_kernel=False)
    assert [c.count for c in host] == [2.0, 1.0, 1.0]
    for got, exp in zip(kern, host):
        np.testing.assert_allclose(got.count, exp.count)
        np.testing.assert_allclose(got.quad, exp.quad, rtol=5e-4, atol=1e-3)


def test_incremental_sharded_cofactors_host_path():
    z = RNG.normal(size=(40, 3))
    base = cofactors_streaming(z, ["a", "b", "c"], chunk_rows=40,
                               use_kernel=False)
    delta = RNG.normal(size=(9, 3))
    out = incremental_sharded_cofactors(base, delta)
    full = np.concatenate([z, delta], 0)
    np.testing.assert_allclose(out.quad, full.T @ full, rtol=1e-6, atol=1e-4)
    # empty delta is the identity
    same = incremental_sharded_cofactors(out, np.zeros((0, 3)))
    assert same is out


# ---------------------------------------------------------------------------
# figure-1 schema sanity (second schema shape through the same machinery)
# ---------------------------------------------------------------------------

def test_append_fig1_schema():
    b = figure1_schema()
    cols = b.features + [b.label]
    b.store.cofactors(b.vorder, cols, backend="numpy")
    delta = Relation.from_columns(
        "d", {"P": [0, 1]}, {"Sale": [5.0, 6.0]}
    )
    b.store.append("Sales", delta)
    warm = b.store.cofactors(b.vorder, cols, backend="numpy")
    cold = cofactors_factorized(b.store, b.vorder, cols, backend="numpy")
    np.testing.assert_allclose(
        warm.matrix(), cold.matrix(), rtol=1e-12, atol=1e-9
    )
