"""Pipeline parallelism: 2-stage GPipe schedule == sequential execution.

Needs 2 devices, so it runs in a subprocess with
``--xla_force_host_platform_device_count=2`` (the main test process must
keep seeing 1 device per the repo's dry-run conventions).
"""

import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import sys; sys.path.insert(0, {src!r})
import jax, jax.numpy as jnp, numpy as np
from repro import sharding as shd
from repro.configs import get_config
from repro.models import model
from repro.train.pipeline import make_pp_loss_for_mesh

cfg = get_config("smollm-135m", smoke=True)  # 2 periods -> 1 per stage
mesh = jax.make_mesh((2, 1), ("pod", "data"))
policy = shd.ShardingPolicy(mesh, shd.TRAIN_RULES)
B, S = 4, 16
key = jax.random.key(0)
params = model.init_params(key, cfg)
batch = {{"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab),
          "labels": jax.random.randint(jax.random.key(1), (B, S), 0,
                                       cfg.vocab)}}
batch_abs = jax.tree.map(
    lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch)
fn, (psh, bsh) = make_pp_loss_for_mesh(
    cfg, mesh, policy, batch_abs, microbatches=2)
params_p = jax.device_put(params, psh)
batch_p = jax.device_put(batch, bsh)
with mesh:
    loss_pp = float(jax.jit(fn)(params_p, batch_p))
loss_seq = float(model.loss_fn(params, batch, cfg)[0])
np.testing.assert_allclose(loss_pp, loss_seq, rtol=2e-5)
g = jax.jit(jax.grad(fn))(params_p, batch_p)
g_seq = jax.grad(lambda p: model.loss_fn(p, batch, cfg)[0])(params)
errs = [float(np.max(np.abs(np.asarray(a, np.float64)
                            - np.asarray(b, np.float64))))
        for a, b in zip(jax.tree.leaves(g), jax.tree.leaves(g_seq))]
assert max(errs) < 1e-4, max(errs)
print("PP_OK")
"""


@pytest.mark.slow
def test_pipeline_matches_sequential():
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    script = _SCRIPT.format(src=os.path.abspath(src))
    env = {k: v for k, v in os.environ.items() if k != "XLA_FLAGS"}
    out = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True, text=True, timeout=600, env=env,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "PP_OK" in out.stdout
