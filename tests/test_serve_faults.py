"""Fault-injection matrix: the service under deterministic seeded faults.

Every test drives :class:`repro.serve.faults.FaultInjector` (wrapping the
real store) through :class:`FactorizedService` and then holds the system
to the same three invariants, whatever was injected:

* **No wedged tickets** — every admitted ticket resolves or fails with a
  typed error; ``run()`` / ``stop()`` always return.
* **Post-fault state ≡ fresh store** — after the faults, reads against
  the (possibly fault-scarred) store match a store rebuilt from scratch
  with the same logical content at 1e-12, and no delta debt lingers.
* **Exact accounting** — per-tenant counters still sum to store totals,
  aborted traversals included (the injector forwards counter increments
  before raising).
"""

import numpy as np
import pytest

from repro.core.factorize import cofactors_factorized
from repro.core.relation import Relation
from repro.core.store import Store
from repro.core.variable_order import VariableOrder
from repro.serve import (
    FactorizedService,
    FaultInjector,
    InjectedFault,
    RetryPolicy,
    TransientInjectedFault,
)

DOMAIN = 8
N_ROWS = 260


def _schema(seed=0):
    """Fact(c0, c1, x, y) ⋈ Dim0(c0, w0) ⋈ Dim1(c1, w1), bushy order."""
    rng = np.random.default_rng(seed)
    keys = {
        f"c{i}": rng.integers(0, DOMAIN, N_ROWS).astype(np.int32)
        for i in range(2)
    }
    x = rng.normal(0, 2.0, N_ROWS)
    y = 0.5 * x + rng.normal(0, 0.5, N_ROWS)
    rels = [
        Relation.from_columns(
            "Fact", keys, {"x": x, "y": y},
            {f"c{i}": DOMAIN for i in range(2)},
        )
    ]
    for i in range(2):
        rels.append(
            Relation.from_columns(
                f"Dim{i}",
                {f"c{i}": rng.integers(0, DOMAIN, 30).astype(np.int32)},
                {f"w{i}": rng.normal(0, 1.0, 30)},
                {f"c{i}": DOMAIN},
            )
        )
    node = VariableOrder(
        "x", [VariableOrder("y", [VariableOrder.leaf("Fact")])]
    )
    for i in reversed(range(2)):
        w = VariableOrder(f"w{i}", [VariableOrder.leaf(f"Dim{i}")])
        node = VariableOrder(f"c{i}", [w, node])
    return rels, VariableOrder.intercept([node])


def _delta(seed=50, n_rows=20):
    rng = np.random.default_rng(seed)
    return Relation.from_columns(
        "delta",
        {
            f"c{i}": rng.integers(0, DOMAIN, n_rows).astype(np.int32)
            for i in range(2)
        },
        {"x": rng.normal(0, 2.0, n_rows), "y": rng.normal(0, 1.0, n_rows)},
    )


def _fresh_matrix(seed, feats, appended=()):
    """Oracle: the same logical content on a never-faulted store."""
    rels, vorder = _schema(seed)
    store = Store(rels)
    for d in appended:
        store.append("Fact", d)
    store.flush()
    return cofactors_factorized(
        store, vorder, list(feats), backend="numpy", use_view_cache=False
    ).matrix()


def _tight(got, want):
    scale = max(1.0, float(np.abs(want).max()))
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12 * scale)


def _assert_consistent(svc, inj, seed, vorder, appended=()):
    """Post-fault closure: state ≡ fresh store at 1e-12, zero delta debt,
    per-tenant counters sum to store totals exactly."""
    inj.disarm()
    feats = ["w0", "w1", "x", "y"]
    t = svc.cofactors("_audit", vorder, feats)
    svc.run()
    _tight(t.result().matrix(), _fresh_matrix(seed, feats, appended))
    assert inj.store.cache_info()["pending_rows"] == 0
    info = svc.cache_info()
    tenants = info["tenants"].values()
    for field in ("passes", "node_visits"):
        assert sum(t[field] for t in tenants) == info[field]
    assert sum(t["vc_hits"] for t in tenants) == info["view_cache_hits"]
    assert sum(t["vc_misses"] for t in tenants) == info["view_cache_misses"]


# ---------------------------------------------------------------------------
# node-visit faults: bisection, retry, exhaustion
# ---------------------------------------------------------------------------

def test_transient_node_fault_bisected_out_of_coalesced_window():
    """A transient fault poisons the MERGED traversal; the service
    bisects, the halves re-run clean (one-shot trap), every ticket
    resolves correctly, nothing is quarantined."""
    rels, vorder = _schema(3)
    inj = FaultInjector(Store(rels), seed=3)
    svc = FactorizedService(inj, backend="numpy", window=4)
    featsets = [["w0", "x", "y"], ["w1", "x", "y"], ["x", "y"], ["w0", "w1", "y"]]
    tickets = [
        svc.cofactors(f"t{i}", vorder, fs) for i, fs in enumerate(featsets)
    ]
    inj.fail_at_node_visit(3, transient=True)
    svc.run()
    assert [k for k, _ in inj.fired] == ["node_visit"]
    for t, fs in zip(tickets, featsets):
        _tight(t.result().matrix(), _fresh_matrix(3, fs))
    info = svc.cache_info()
    assert info["retries"] == 0 and info["quarantined"] == 0
    _assert_consistent(svc, inj, 3, vorder)


def test_poisoned_request_isolated_by_bisection():
    """One genuinely bad request in a coalesced window fails ALONE: the
    bisection narrows the failure to it, quarantines it, and serves the
    three innocent co-riders correctly."""
    rels, vorder = _schema(4)
    inj = FaultInjector(Store(rels), seed=4)
    svc = FactorizedService(inj, backend="numpy", window=4)
    good_fs = [["w0", "x", "y"], ["x", "y"], ["w1", "y"]]
    good = [svc.cofactors(f"g{i}", vorder, fs) for i, fs in enumerate(good_fs)]
    bad = svc.cofactors("evil", vorder, ["no_such_feature", "x"])
    svc.run()
    # noqa-reason: the engine's raise type for a bad feature list is an
    # implementation detail; the test asserts propagation + isolation
    with pytest.raises(Exception):  # noqa: B017
        bad.result()
    for t, fs in zip(good, good_fs):
        _tight(t.result().matrix(), _fresh_matrix(4, fs))
    info = svc.cache_info()
    assert info["quarantined"] == 1
    assert info["tenants"]["evil"]["failures"] == 1
    (rec,) = svc.quarantined()
    assert rec["tenant"] == "evil" and rec["kind"] == "cofactors"
    _assert_consistent(svc, inj, 4, vorder)


def test_retry_with_backoff_recovers_transient_fault():
    rels, vorder = _schema(5)
    inj = FaultInjector(Store(rels), seed=5)
    svc = FactorizedService(
        inj, backend="numpy",
        retry=RetryPolicy(max_attempts=3, backoff=0.001),
    )
    t = svc.cofactors("a", vorder, ["w0", "x", "y"])
    inj.fail_at_node_visit(2, transient=True)
    svc.run()
    _tight(t.result().matrix(), _fresh_matrix(5, ["w0", "x", "y"]))
    info = svc.cache_info()
    assert info["retries"] == 1
    assert info["tenants"]["a"]["retries"] == 1
    assert info["quarantined"] == 0  # recovered, not quarantined
    _assert_consistent(svc, inj, 5, vorder)


def test_retry_exhaustion_fails_ticket_without_wedging():
    """Under a near-certain per-visit hazard every retry fails too: the
    ticket fails typed after max_attempts, is quarantined with its
    attempt count, and the service keeps serving."""
    rels, vorder = _schema(6)
    inj = FaultInjector(Store(rels), seed=6)
    svc = FactorizedService(
        inj, backend="numpy",
        retry=RetryPolicy(max_attempts=2, backoff=0.0005),
    )
    inj.arm_random_node_faults(0.95, transient=True)
    t = svc.cofactors("a", vorder, ["x", "y"])
    svc.run()  # returns: no wedge even when everything faults
    with pytest.raises(TransientInjectedFault):
        t.result()
    (rec,) = svc.quarantined()
    assert rec["attempts"] == 2
    assert svc.cache_info()["retries"] == 1
    _assert_consistent(svc, inj, 6, vorder)


def test_terminal_fault_fails_fast_despite_retry_policy():
    rels, vorder = _schema(7)
    inj = FaultInjector(Store(rels), seed=7)
    svc = FactorizedService(
        inj, backend="numpy", retry=RetryPolicy(max_attempts=5)
    )
    inj.fail_at_node_visit(2, transient=False)  # NOT retryable
    t = svc.cofactors("a", vorder, ["x", "y"])
    svc.run()
    with pytest.raises(InjectedFault):
        t.result()
    assert svc.cache_info()["retries"] == 0
    _assert_consistent(svc, inj, 7, vorder)


# ---------------------------------------------------------------------------
# fold faults: lazy drain, idle flush, eager append
# ---------------------------------------------------------------------------

def test_poisoned_idle_fold_absorbed_and_state_recovers():
    """A fold that dies mid-drain is absorbed by the service (counted +
    quarantined, never raised at a caller); the store's exception path
    invalidated the half-folded entries, so the very next read recomputes
    and matches a fresh store exactly."""
    rels, vorder = _schema(8)
    inj = FaultInjector(Store(rels), seed=8)
    svc = FactorizedService(inj, backend="numpy", flush_policy="never")
    svc.cofactors("reader", vorder, ["w0", "x", "y"])
    svc.run()  # warm caches → the append below leaves real fold debt
    d = _delta(51)
    svc.append("writer", "Fact", d)
    svc.run()
    assert inj.store.cache_info()["pending_rows"] > 0
    inj.fail_next_fold(transient=False)
    stats = svc.flush()  # absorbed, not raised
    assert stats["rows"] == 0
    assert [k for k, _ in inj.fired] == ["fold"]
    info = svc.cache_info()
    assert info["fold_failures"] == 1
    recs = svc.quarantined()
    assert recs and recs[-1]["kind"] == "fold"
    _assert_consistent(svc, inj, 8, vorder, appended=[d])


def test_poisoned_read_barrier_fold_retried_to_success():
    """A transient fold fault at the drain cycle's read barrier is
    absorbed; the retry path (recompute on invalidated entries) serves
    the read correctly in the same run."""
    rels, vorder = _schema(9)
    inj = FaultInjector(Store(rels), seed=9)
    svc = FactorizedService(
        inj, backend="numpy",
        retry=RetryPolicy(max_attempts=3, backoff=0.001),
    )
    svc.cofactors("reader", vorder, ["w1", "x", "y"])
    svc.run()
    d = _delta(52)
    svc.append("writer", "Fact", d)
    svc.run()
    inj.fail_next_fold(transient=True)
    t = svc.cofactors("reader", vorder, ["w1", "x", "y"])
    svc.run()
    _tight(t.result().matrix(), _fresh_matrix(9, ["w1", "x", "y"], [d]))
    _assert_consistent(svc, inj, 9, vorder, appended=[d])


def test_eager_poisoned_append_rejected_store_untouched():
    """Under eager maintenance a poisoned delta raises out of the append
    with the catalog EXACTLY as before: the write ticket fails, readers
    never see a partial append."""
    rels, vorder = _schema(10)
    inj = FaultInjector(Store(rels, maintenance="eager"), seed=10)
    svc = FactorizedService(inj, backend="numpy")
    svc.cofactors("reader", vorder, ["w0", "x", "y"])
    svc.run()  # caches populated → the append has entries to fold into
    inj.fail_next_fold(transient=False)
    bad = svc.append("writer", "Fact", _delta(53))
    svc.run()
    with pytest.raises(InjectedFault):
        bad.result()
    assert svc.cache_info()["tenants"]["writer"]["failures"] == 1
    # catalog untouched: state ≡ fresh store WITHOUT the delta
    _assert_consistent(svc, inj, 10, vorder, appended=())


# ---------------------------------------------------------------------------
# cache-pressure storms
# ---------------------------------------------------------------------------

def test_eviction_storms_never_change_results():
    """Evicting the ENTIRE view cache at every snapshot forces cold
    recomputes mid-workload: results stay exact, only the hit/miss mix
    moves."""
    rels, vorder = _schema(11)
    inj = FaultInjector(Store(rels), seed=11)
    svc = FactorizedService(inj, backend="numpy")
    inj.arm_eviction_storms(every_snapshots=1)
    feats = ["w0", "w1", "x", "y"]
    d = _delta(55)
    tickets = []
    for _ in range(3):
        tickets.append(svc.cofactors("a", vorder, feats))
        # a write per cycle republishes the snapshot → storm fires
        svc.append("writer", "Fact", d)
        svc.drain()
    final = svc.cofactors("a", vorder, feats)
    svc.run()
    for t, k in zip(tickets, (0, 1, 2)):
        _tight(
            t.result().matrix(), _fresh_matrix(11, feats, appended=[d] * k)
        )
    want = _fresh_matrix(11, feats, appended=[d] * 3)
    _tight(final.result().matrix(), want)
    assert any(k == "evict_storm" for k, _ in inj.fired)
    assert inj.store.view_cache.evictions > 0
    inj.disarm()
    # post-storm warm path works again and counters audit (vc_bytes is
    # excluded: storms drop bytes outside request brackets by design)
    t = svc.cofactors("b", vorder, feats)
    svc.run()
    _tight(t.result().matrix(), want)
    info = svc.cache_info()
    tenants = info["tenants"].values()
    for field in ("passes", "node_visits"):
        assert sum(t[field] for t in tenants) == info[field]
    assert sum(t["vc_hits"] for t in tenants) == info["view_cache_hits"]
    assert sum(t["vc_misses"] for t in tenants) == info["view_cache_misses"]
    assert inj.store.cache_info()["pending_rows"] == 0


# ---------------------------------------------------------------------------
# threaded runtime under randomized faults: the no-wedge theorem
# ---------------------------------------------------------------------------

def test_threaded_runtime_under_random_faults_no_wedged_tickets():
    """The full gauntlet: threaded runtime, random per-visit hazard,
    eviction storms, and a mid-run fold trap.  Every ticket resolves
    (value or typed error), the drained store equals a fresh one, and
    the accounting still sums — determinism comes from the seeded
    injector, not from the schedule."""
    from repro.serve import RuntimeConfig

    rels, vorder = _schema(12)
    inj = FaultInjector(Store(rels), seed=12)
    svc = FactorizedService(
        inj, backend="numpy", window=3,
        retry=RetryPolicy(max_attempts=3, backoff=0.0005),
    )
    inj.arm_random_node_faults(0.02, transient=True)
    inj.arm_eviction_storms(every_snapshots=3)
    inj.fail_next_fold(nth=2, transient=True)
    svc.start(RuntimeConfig(poll_interval=0.002, fold_interval=0.004))
    d = _delta(54)
    featsets = [["w0", "x", "y"], ["w1", "x", "y"], ["x", "y"]]
    tickets = []
    n_appends = 0
    for i in range(24):
        if i % 6 == 5:
            tickets.append(svc.append("writer", "Fact", d))
            n_appends += 1
        else:
            fs = featsets[i % len(featsets)]
            tickets.append(svc.cofactors(f"t{i % 3}", vorder, fs))
    svc.stop(drain=True, timeout=60)
    resolved = 0
    for t in tickets:
        assert t.done, "wedged ticket"
        try:
            t.result()
            resolved += 1
        except Exception:
            pass  # typed failure is a legal outcome under injected faults
    assert resolved > 0  # the hazard is mild: most requests succeed
    svc2 = FactorizedService(inj, backend="numpy")
    inj.disarm()
    feats = ["w0", "w1", "x", "y"]
    t = svc2.cofactors("_audit", vorder, feats)
    svc2.run()
    _tight(
        t.result().matrix(),
        _fresh_matrix(12, feats, appended=[d] * n_appends),
    )
    assert inj.store.cache_info()["pending_rows"] == 0
